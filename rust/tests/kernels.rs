//! Kernel-layer golden + property tests: the fused, chunk-parallel round
//! hot path must be BIT-IDENTICAL to the pre-refactor scalar path for a
//! fixed seed — at threads = 1 (replicating the historical sequential
//! implementation verbatim) and at threads > 1 (ordered chunk grids,
//! skip-ahead noise).  A Coordinator-level golden (artifacts-gated) pins
//! the same contract end-to-end through `Coordinator::run()`.

use mpota::channel::{ChannelConfig, RoundChannel};
use mpota::config::RunConfig;
use mpota::coordinator::Coordinator;
use mpota::fl::Scheme;
use mpota::kernels::{fused, PayloadPlane};
use mpota::ota::analog::{aggregate_plane_into, OtaScratch};
use mpota::quant::{self, Precision, Rounding};
use mpota::rng::Rng;
use mpota::tensor;

/// The pre-refactor scalar path lives in `mpota::testing` so the golden
/// tests and the `hotpaths` bench pin against the SAME baseline.
use mpota::testing::reference_ota_aggregate as pre_refactor_aggregate;

fn gaussian_payloads(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::seed_from(seed);
    (0..k)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect()
}

#[test]
fn analog_aggregation_bit_identical_to_pre_refactor() {
    // shapes: large-even (parallel kernels + parallel noise), odd
    // (noise falls back, superposition still chunks), small (all
    // sequential), each with a truncation that silences some clients
    let cases = [
        (15usize, 20_000usize, 20.0f32, 0.5f32),
        (8, 9_999, 10.0, 0.8),
        (4, 100, 25.0, 0.1),
    ];
    for (ci, &(k, n, snr, trunc)) in cases.iter().enumerate() {
        let payloads = gaussian_payloads(k, n, 100 + ci as u64);
        let cfg = ChannelConfig {
            snr_db: snr,
            truncation: trunc,
            ..Default::default()
        };
        let mut ch_rng = Rng::seed_from(200 + ci as u64);
        let round = RoundChannel::draw(&cfg, k, &mut ch_rng);

        let mut ref_rng = Rng::seed_from(300 + ci as u64);
        let (want, want_parts, want_mse) =
            pre_refactor_aggregate(&payloads, &round, &mut ref_rng);
        let ref_next = ref_rng.next_u64();

        let plane = PayloadPlane::from_rows(&payloads);
        let mut scratch = OtaScratch::new();
        for threads in [1usize, 2, 4] {
            let mut rng = Rng::seed_from(300 + ci as u64);
            let stats = aggregate_plane_into(&plane, &round, &mut rng, &mut scratch, threads);
            assert_eq!(stats.participants, want_parts, "case {ci} threads {threads}");
            assert_eq!(
                scratch.y_re, want,
                "case {ci} threads {threads}: aggregate diverged"
            );
            assert_eq!(
                stats.mse_vs_ideal.to_bits(),
                want_mse.to_bits(),
                "case {ci} threads {threads}: mse diverged"
            );
            // generator must land on exactly the same stream position
            if want_parts > 0 {
                assert_eq!(rng.next_u64(), ref_next, "case {ci} threads {threads}");
            }
        }
    }
}

#[test]
fn payload_build_bit_identical_to_pre_refactor() {
    // client-side payload construction: fused layout-quantize-into + fused
    // diff vs the historical allocate-copy-quantize-subtract chain
    let layout = mpota::tensor::ParamLayout::from_manifest(
        &mpota::json::parse(r#"[["conv", [3, 3, 16]], ["dense", [400, 43]], ["b", [43]]]"#)
            .unwrap(),
    )
    .unwrap();
    let mut rng = Rng::seed_from(7);
    let mut theta_global = vec![0.0f32; layout.total];
    rng.fill_normal(&mut theta_global, 0.0, 0.5);
    let mut theta_trained = theta_global.clone();
    // pretend training moved the weights a bit
    let mut delta = vec![0.0f32; layout.total];
    rng.fill_normal(&mut delta, 0.0, 0.01);
    tensor::axpy(&mut theta_trained, 1.0, &delta);

    for bits in [16u8, 8, 4] {
        let p = Precision::of(bits);
        // pre-refactor chain
        let theta_start = quant::fake_quant_layout(&theta_global, &layout, p, Rounding::Nearest);
        let want: Vec<f32> = theta_trained
            .iter()
            .zip(theta_start.iter())
            .map(|(a, b)| a - b)
            .collect();
        // fused chain at both thread counts
        for threads in [1usize, 4] {
            let mut start = vec![0.0f32; layout.total];
            quant::fake_quant_layout_into(
                &mut start,
                &theta_global,
                &layout,
                p,
                Rounding::Nearest,
                threads,
            );
            let mut payload = vec![0.0f32; layout.total];
            tensor::diff_into(&mut payload, &theta_trained, &start);
            let same = payload
                .iter()
                .zip(want.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "bits={bits} threads={threads}");
        }
    }
}

#[test]
fn property_fused_axpy2_matches_naive() {
    mpota::testing::check(
        "axpy2-vs-naive",
        48,
        |rng| {
            let n = 1 + rng.below(600);
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut x, 0.0, 3.0);
            let g_re = rng.normal_f32(0.0, 1.0);
            let g_im = rng.normal_f32(0.0, 1.0);
            (x, g_re, g_im)
        },
        |(x, g_re, g_im)| {
            let n = x.len();
            let mut want_re = vec![0.5f32; n];
            let mut want_im = vec![-0.5f32; n];
            tensor::axpy(&mut want_re, *g_re, x);
            tensor::axpy(&mut want_im, *g_im, x);
            let mut y_re = vec![0.5f32; n];
            let mut y_im = vec![-0.5f32; n];
            fused::axpy2(
                &mut y_re,
                &mut y_im,
                mpota::channel::C32::new(*g_re, *g_im),
                x,
            );
            y_re == want_re && y_im == want_im
        },
    );
}

#[test]
fn property_plane_roundtrip_preserves_rows() {
    mpota::testing::check(
        "plane-roundtrip",
        32,
        |rng| {
            let k = 1 + rng.below(8);
            let n = 1 + rng.below(300);
            (0..k)
                .map(|_| {
                    let mut v = vec![0.0f32; n];
                    rng.fill_normal(&mut v, 0.0, 2.0);
                    v
                })
                .collect::<Vec<_>>()
        },
        |rows| {
            let p = PayloadPlane::from_rows(rows);
            p.k() == rows.len()
                && p.rows().zip(rows.iter()).all(|(a, b)| a == b.as_slice())
        },
    );
}

// ---------------------------------------------------------------- golden
// Coordinator-level determinism: threads = 1 must equal threads = 4 over a
// full run, bit for bit, in round records and the final model.  Needs the
// PJRT artifacts (skips gracefully like the other integration suites).

fn artifacts_present() -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature (PJRT execution stubbed)");
        return false;
    }
    let dir = std::path::PathBuf::from(
        std::env::var("MPOTA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let ok = dir.join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
    }
    ok
}

#[test]
fn coordinator_run_identical_across_thread_counts() {
    if !artifacts_present() {
        return;
    }
    let run = |threads: usize| {
        let mut cfg = RunConfig::default();
        cfg.rounds = 2;
        cfg.train_samples = 480;
        cfg.test_samples = 96;
        cfg.local_steps = 1;
        cfg.scheme = Scheme::parse("16,8,4").unwrap();
        cfg.seed = 1234;
        cfg.threads = threads;
        let mut coord = Coordinator::new(cfg).unwrap();
        let report = coord.run().unwrap();
        let records: Vec<(u64, u64, usize)> = report
            .log
            .rounds
            .iter()
            .map(|r| (r.train_loss.to_bits(), r.ota_mse.to_bits(), r.participants))
            .collect();
        let model: Vec<u32> = coord.global_model().iter().map(|v| v.to_bits()).collect();
        (records, model)
    };
    let (rec1, model1) = run(1);
    let (rec4, model4) = run(4);
    assert_eq!(rec1, rec4, "round records diverged across thread counts");
    assert_eq!(model1, model4, "final model diverged across thread counts");
}
