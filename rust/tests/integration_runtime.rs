//! Integration tests over the PJRT runtime + artifacts: the L1/L2 graphs
//! executed from rust must behave as the model contract promises.
//!
//! All tests skip gracefully when `artifacts/` has not been built.

use mpota::data::{Dataset, SAMPLE_LEN};
use mpota::ota;
use mpota::quant::Precision;
use mpota::rng::Rng;
use mpota::runtime::Runtime;
use mpota::channel::{ChannelConfig, RoundChannel};

fn runtime() -> Option<Runtime> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature (PJRT execution stubbed)");
        return None;
    }
    let dir = std::path::PathBuf::from(
        std::env::var("MPOTA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

fn batch(rt: &Runtime, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::seed_from(seed);
    let b = rt.manifest.train_batch;
    let data = Dataset::generate(b, &mut rng);
    (data.images.clone(), data.labels.clone())
}

#[test]
fn train_step_contract() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init_params("base").unwrap();
    let (images, labels) = batch(&rt, 1);
    let out = rt
        .train_step("base", Precision::of(8), &theta, &images, &labels, 0.05)
        .unwrap();
    assert_eq!(out.new_theta.len(), theta.len());
    // first step from He init: uniform softmax over 43 classes
    assert!((out.loss - (43.0f32).ln()).abs() < 0.05, "loss {}", out.loss);
    assert!(out.correct >= 0.0 && out.correct <= rt.manifest.train_batch as f32);
    // params actually moved
    assert!(mpota::tensor::max_abs_diff(&out.new_theta, &theta) > 0.0);
}

#[test]
fn train_overfits_single_batch_f32() {
    let Some(rt) = runtime() else { return };
    let mut theta = rt.init_params("base").unwrap();
    let (images, labels) = batch(&rt, 2);
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..10 {
        let out = rt
            .train_step("base", Precision::of(32), &theta, &images, &labels, 0.2)
            .unwrap();
        theta = out.new_theta;
        first.get_or_insert(out.loss);
        last = out.loss;
    }
    assert!(
        last < first.unwrap() - 0.5,
        "no learning: first {} last {last}",
        first.unwrap()
    );
}

#[test]
fn low_precision_params_stay_coarse() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init_params("base").unwrap();
    let (images, labels) = batch(&rt, 3);
    let out = rt
        .train_step("base", Precision::of(4), &theta, &images, &labels, 0.05)
        .unwrap();
    // per-tensor 4-bit quantization: whole-vector distinct count is bounded
    // by 16 levels per parameter tensor; the flat concat of 14 tensors can
    // hold at most 14 * 16 distinct values
    let mut distinct: Vec<f32> = out.new_theta.clone();
    distinct.sort_by(f32::total_cmp);
    distinct.dedup();
    assert!(
        distinct.len() <= 14 * 16,
        "4-bit params have {} distinct values",
        distinct.len()
    );
}

#[test]
fn evaluate_handles_ragged_batches() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init_params("base").unwrap();
    let mut rng = Rng::seed_from(4);
    // 70 samples: one full eval batch of 64 + ragged 6
    let data = Dataset::generate(70, &mut rng);
    let r = rt
        .evaluate("base", &theta, &data.images, &data.labels)
        .unwrap();
    assert_eq!(r.samples, 70);
    // zero-init classifier head => exactly uniform predictions
    assert!((r.loss - (43.0f64).ln()).abs() < 0.05, "loss {}", r.loss);
    assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0);

    // consistency: evaluating twice gives identical numbers
    let r2 = rt
        .evaluate("base", &theta, &data.images, &data.labels)
        .unwrap();
    assert_eq!(r.loss, r2.loss);
    assert_eq!(r.accuracy, r2.accuracy);
}

#[test]
fn eval_batch_boundary_exact_multiple() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init_params("base").unwrap();
    let mut rng = Rng::seed_from(5);
    let eb = rt.manifest.eval_batch;
    let data = Dataset::generate(eb * 2, &mut rng);
    let r = rt
        .evaluate("base", &theta, &data.images, &data.labels)
        .unwrap();
    assert_eq!(r.samples, eb * 2);
}

/// The L1 Pallas OTA kernel (through PJRT) and the rust hot path must
/// compute the same superposition.
#[test]
fn ota_artifact_cross_validates_rust_hot_path() {
    let Some(rt) = runtime() else { return };
    let k = rt.manifest.ota.clients;
    let chunk = rt.manifest.ota.chunk;
    let mut rng = Rng::seed_from(6);

    // payloads + a realistic imperfect-CSI channel round
    let payloads: Vec<Vec<f32>> = (0..k)
        .map(|_| {
            let mut v = vec![0.0f32; chunk];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect();
    let cfg = ChannelConfig::default();
    let round = RoundChannel::draw(&cfg, k, &mut rng);
    let (gre, gim) = ota::analog::gain_vectors(&round);
    let noise_re = vec![0.0f32; chunk];
    let noise_im = vec![0.0f32; chunk];

    // PJRT path
    let mut flat = Vec::with_capacity(k * chunk);
    for p in &payloads {
        flat.extend_from_slice(p);
    }
    let (pjrt_re, _pjrt_im) = rt
        .ota_chunk(&flat, &gre, &gim, &noise_re, &noise_im)
        .unwrap();

    // rust path (no noise => deterministic comparison); aggregate() scales
    // by participants, the kernel does not — undo the scaling.
    let mut noise_rng = Rng::seed_from(7);
    let mut silent_cfg = round.clone();
    silent_cfg.snr_db = f32::INFINITY; // noise_var -> 0
    let (rust_mean, stats) =
        ota::analog::aggregate(&payloads, &silent_cfg, &mut noise_rng);
    let scale = stats.participants as f32;
    let rust_sum: Vec<f32> = rust_mean.iter().map(|v| v * scale).collect();

    // silenced clients have zero gain in BOTH paths; compare elementwise
    let max_diff = mpota::tensor::max_abs_diff(&pjrt_re, &rust_sum);
    assert!(max_diff < 2e-3, "pallas vs rust OTA diverge: {max_diff}");
}

#[test]
fn executable_cache_compiles_once() {
    let Some(rt) = runtime() else { return };
    let theta = rt.init_params("base").unwrap();
    let (images, labels) = batch(&rt, 8);
    for _ in 0..3 {
        rt.train_step("base", Precision::of(16), &theta, &images, &labels, 0.01)
            .unwrap();
    }
    let c = rt.counters();
    assert_eq!(c.compiles, 1, "executable cache miss: {c:?}");
    assert_eq!(c.train_steps, 3);
}

#[test]
fn variant_artifacts_all_loadable() {
    let Some(rt) = runtime() else { return };
    for (name, v) in rt.manifest.variants.clone() {
        let theta = rt.init_params(&name).unwrap();
        assert_eq!(theta.len(), v.param_count, "{name}");
        let mut rng = Rng::seed_from(9);
        let data = Dataset::generate(rt.manifest.eval_batch, &mut rng);
        let r = rt
            .evaluate(&name, &theta, &data.images, &data.labels)
            .unwrap();
        assert!(r.loss.is_finite(), "{name}");
    }
    let _ = SAMPLE_LEN; // silence unused import on skip path
}
