//! Statistical acceptance tests for the deadline/dropout straggler engine
//! (PR 6): empirical exclusion rates match the configured processes, the
//! partial-participation divisor is exact, and convergence degrades
//! monotonically with dropout.
//!
//! All fixtures run the deterministic mock backend, so every assertion
//! here is reproducible bit-for-bit; the "statistical" part is that the
//! tolerances were sized from the binomial standard error of the fixture
//! (≥ 3σ margins), not hand-tuned to the seed.

use std::rc::Rc;

use mpota::channel::FadingKind;
use mpota::config::{Aggregation, RunConfig};
use mpota::coordinator::RunReport;
use mpota::fl::Scheme;
use mpota::kernels::PayloadPlane;
use mpota::quant::Precision;
use mpota::rng::Rng;
use mpota::runtime::Runtime;
use mpota::sim::{aggregator, channel_model, Experiment, Session, VirtualClock};
use mpota::testing::{mock_artifacts_dir, MockTrainer};

fn base_cfg(dir: &std::path::Path) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = dir.to_path_buf();
    cfg.variant = "mock".into();
    cfg.clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = 3;
    cfg.train_samples = 96;
    cfg.test_samples = 32;
    cfg.scheme = Scheme::parse("16,8,4").unwrap();
    cfg.channel.model = FadingKind::Rayleigh;
    cfg
}

fn run(cfg: RunConfig, rt: Rc<Runtime>) -> (Vec<u32>, RunReport) {
    let mut exp = Experiment::builder(cfg)
        .runtime(rt)
        .backend(MockTrainer)
        .build()
        .unwrap();
    let report = exp.run().unwrap();
    let bits: Vec<u32> = exp.global_model().iter().map(|v| v.to_bits()).collect();
    (bits, report)
}

/// Fraction of selected slots excluded over the whole run.
fn exclusion_rate(report: &RunReport, k: usize) -> f64 {
    let rounds = report.log.rounds.len();
    let present: usize = report.log.rounds.iter().map(|r| r.participants).sum();
    1.0 - present as f64 / (rounds * k) as f64
}

#[test]
fn empirical_dropout_exclusion_rate_matches_p() {
    // i.i.d. Bernoulli(0.25) dropout over 150 rounds x 6 slots = 900
    // draws: the empirical exclusion rate must land within 0.05 of p
    // (3.5 sigma of the binomial mean)
    let dir = mock_artifacts_dir("dropstats_rate");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mut cfg = base_cfg(&dir);
    cfg.rounds = 150;
    cfg.dropout_p = 0.25;
    cfg.aggregation = Aggregation::Ideal;
    let (_, report) = run(cfg, rt);
    let rate = exclusion_rate(&report, 6);
    assert!(
        (rate - 0.25).abs() < 0.05,
        "empirical dropout rate {rate:.4} not within 0.05 of p = 0.25"
    );
    // and the process actually varies round to round (not a stuck mask)
    let parts: Vec<usize> =
        report.log.rounds.iter().map(|r| r.participants).collect();
    assert!(parts.iter().any(|&p| p != parts[0]), "dropout mask never varied");
}

#[test]
fn deadline_misses_match_the_virtual_clock_theory() {
    // all-8-bit fleet under a deadline chosen to sit well inside the
    // lognormal latency distribution (analytic miss prob ~ 0.325):
    // the empirical rate over 200 rounds x 6 slots = 1200 samples must
    // match VirtualClock::miss_probability within 0.05 (3.7 sigma)
    let dir = mock_artifacts_dir("dropstats_deadline");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mut cfg = base_cfg(&dir);
    cfg.rounds = 200;
    cfg.scheme = Scheme::parse("8,8,8").unwrap();
    cfg.aggregation = Aggregation::Ideal;
    cfg.deadline_s = 0.019;
    cfg.compute_s = 0.05;
    cfg.latency_jitter = 0.25;
    cfg.slot_s = 0.005;
    let theory = VirtualClock::new(&cfg).miss_probability(8);
    assert!(
        theory > 0.05 && theory < 0.95,
        "fixture is degenerate: analytic miss probability {theory:.4}"
    );
    let (_, report) = run(cfg, rt);
    let rate = exclusion_rate(&report, 6);
    assert!(
        (rate - theory).abs() < 0.05,
        "empirical miss rate {rate:.4} not within 0.05 of theory {theory:.4}"
    );
}

#[test]
fn partial_participation_divisor_is_exact() {
    // session-level pin: with 2 of 4 slots transmitting, the ideal
    // aggregate is EXACTLY (r0 + r2) / 2 — the divisor is the number of
    // transmitters, not the number of selected clients
    let cfg = RunConfig::default();
    let n = 33usize;
    let root = Rng::seed_from(7);
    // strictly positive rows so the f32 sum has no signed-zero edge cases
    let rows: Vec<Vec<f32>> = (0..4)
        .map(|k| (0..n).map(|i| 1.0 + k as f32 + i as f32 * 0.25).collect())
        .collect();
    let plane = PayloadPlane::from_rows(&rows);
    let precisions = vec![Precision::of(8); 4];
    let mask = [true, false, true, false];

    let mut session = Session::new(
        channel_model::from_config(&cfg.channel),
        aggregator::from_config(Aggregation::Ideal),
        root.stream("channel"),
        root.stream("noise"),
        1,
    );
    session.begin_aggregate_partial(1, 4, 2, n);
    session.accumulate_shard_masked(&plane, 0, &precisions, Some(&mask));
    let stats = session.finalize_aggregate(1, &precisions);
    assert_eq!(stats.participants, 2, "ideal participants over transmitters");
    for i in 0..n {
        let want = 0.5f32 * rows[0][i] + 0.5f32 * rows[2][i];
        assert_eq!(
            session.result()[i].to_bits(),
            want.to_bits(),
            "ideal divisor not exact at element {i}"
        );
    }

    // digital baseline: masked rows consume neither bits nor channel uses
    let mut session = Session::new(
        channel_model::from_config(&cfg.channel),
        aggregator::from_config(Aggregation::Digital),
        root.stream("channel"),
        root.stream("noise"),
        1,
    );
    session.begin_aggregate_partial(1, 4, 2, n);
    session.accumulate_shard_masked(&plane, 0, &precisions, Some(&mask));
    let stats = session.finalize_aggregate(1, &precisions);
    assert_eq!(stats.participants, 2, "digital participants over transmitters");
    assert_eq!(stats.bits_transmitted, 2 * 8 * n as u64);
    assert_eq!(stats.channel_uses, 2 * n as u64);
}

#[test]
fn convergence_degrades_monotonically_with_dropout() {
    // i.i.d. dropout draws one uniform per slot from the dedicated
    // "straggler" stream REGARDLESS of p, so runs differing only in p
    // compare the SAME uniforms against nested thresholds: exclusion sets
    // are nested (E(0.3) is a subset of E(0.6)) and participation is
    // monotone by construction, not just in expectation
    let dir = mock_artifacts_dir("dropstats_monotone");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |p: f64| {
        let mut cfg = base_cfg(&dir);
        cfg.rounds = 24;
        cfg.aggregation = Aggregation::OtaAnalog;
        cfg.channel.snr_db = 0.0; // noise-dominated: divisor loss visible
        cfg.dropout_p = p;
        cfg
    };
    let runs: Vec<(Vec<u32>, RunReport)> =
        [0.0, 0.3, 0.6].iter().map(|&p| run(mk(p), rt.clone())).collect();

    // per-round nesting: participants never increase with p
    for (a, b) in runs.windows(2).map(|w| (&w[0].1, &w[1].1)) {
        for (ra, rb) in a.log.rounds.iter().zip(b.log.rounds.iter()) {
            assert!(
                rb.participants <= ra.participants,
                "round {}: participation rose with dropout_p",
                ra.round
            );
        }
    }
    // and strictly fewer slots delivered in total at each step up in p
    let totals: Vec<usize> = runs
        .iter()
        .map(|(_, r)| r.log.rounds.iter().map(|x| x.participants).sum())
        .collect();
    assert!(
        totals[0] > totals[1] && totals[1] > totals[2],
        "total participation not strictly decreasing: {totals:?}"
    );

    // OTA error grows as the divisor shrinks (1/active_k^2 noise scaling):
    // mean over delivered rounds at p = 0.6 exceeds the clean run
    let mean_mse = |r: &RunReport| {
        let delivered: Vec<f64> = r
            .log
            .rounds
            .iter()
            .filter(|x| x.participants > 0)
            .map(|x| x.ota_mse)
            .collect();
        assert!(!delivered.is_empty());
        delivered.iter().sum::<f64>() / delivered.len() as f64
    };
    assert!(
        mean_mse(&runs[2].1) > mean_mse(&runs[0].1),
        "mean OTA MSE did not grow under heavy dropout"
    );

    // dropout changes the trajectory: lossy finals differ from the clean one
    assert_ne!(runs[0].0, runs[1].0, "p = 0.3 reproduced the clean model");
    assert_ne!(runs[0].0, runs[2].0, "p = 0.6 reproduced the clean model");
}
