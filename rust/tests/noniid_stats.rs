//! NON-IID partition statistics and convergence-science pins.
//!
//! The Dirichlet(α) partitioner (`mpota::data::dirichlet_recipe`,
//! Hsu-style per-class Dirichlet over clients with optional power-law
//! sample-count skew) feeds the convergence suite: the deterministic
//! [`GradStatsBackend`] turns each client's label marginal into a
//! displaced synthetic optimum, so the classic federated pathologies —
//! IID converges faster than α=1.0, which converges faster than α=0.1;
//! aggregation noise slows every partition — are measurable, ordered and
//! seed-deterministic without PJRT hardware.
//!
//! Statistical checks: per-client label-marginal chi-square against the
//! corpus marginal (α=100 ≈ uniform, α=0.1 heavy single-label), the Zipf
//! sample-count tail, exact single-assignment cover, and per-seed
//! determinism of both the recipe and the full-FL trajectory at
//! `threads` 1 and 4.

use std::rc::Rc;

use mpota::config::{Aggregation, PartitionKind, RunConfig};
use mpota::data::{dirichlet_recipe, Dataset, PartitionRecipe, NUM_CLASSES};
use mpota::fl::Scheme;
use mpota::rng::Rng;
use mpota::runtime::Runtime;
use mpota::sim::Experiment;
use mpota::testing::{mock_artifacts_dir, GradStatsBackend};

/// A perfectly class-balanced synthetic label vector (n/NUM_CLASSES
/// samples per class) — isolates the partitioner's skew from corpus skew.
fn balanced_labels(n: usize) -> Vec<i32> {
    (0..n).map(|i| (i % NUM_CLASSES) as i32).collect()
}

/// Per-client chi-square statistic of the shard's label histogram against
/// the corpus marginal (dof = NUM_CLASSES - 1 = 42 when balanced).
fn per_client_chi2(labels: &[i32], recipe: &PartitionRecipe) -> Vec<f64> {
    let n = labels.len() as f64;
    let mut global = vec![0f64; NUM_CLASSES];
    for &l in labels {
        global[l as usize] += 1.0;
    }
    (0..recipe.clients())
        .map(|c| {
            let shard = recipe.shard_of(c);
            let s = shard.len() as f64;
            let mut o = vec![0f64; NUM_CLASSES];
            for &i in shard {
                o[labels[i] as usize] += 1.0;
            }
            (0..NUM_CLASSES)
                .map(|k| {
                    let e = s * global[k] / n;
                    (o[k] - e).powi(2) / e
                })
                .sum()
        })
        .collect()
}

/// Mean over clients of the share the client's most common label takes.
fn mean_top_label_share(labels: &[i32], recipe: &PartitionRecipe) -> f64 {
    let shares: Vec<f64> = (0..recipe.clients())
        .map(|c| {
            let shard = recipe.shard_of(c);
            let mut o = vec![0usize; NUM_CLASSES];
            for &i in shard {
                o[labels[i] as usize] += 1;
            }
            *o.iter().max().unwrap() as f64 / shard.len() as f64
        })
        .collect();
    shares.iter().sum::<f64>() / shares.len() as f64
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len() as f64
}

#[test]
fn dirichlet_concentration_tracks_alpha() {
    // 200 samples per class, 10 clients: at α=100 every client's marginal
    // hugs the corpus marginal (chi-square far below the heavy-skew
    // regime); at α=0.1 each class lands mostly on one client, so shards
    // are dominated by a few labels and the statistic explodes
    let labels = balanced_labels(NUM_CLASSES * 200);
    let mk = |alpha: f64| {
        let mut rng = Rng::seed_from(5).stream("chi");
        dirichlet_recipe(&labels, 10, alpha, 0.0, 8, &mut rng).unwrap()
    };
    let near_iid = mk(100.0);
    let skewed = mk(0.1);
    let chi_near = mean(&per_client_chi2(&labels, &near_iid));
    let chi_far = mean(&per_client_chi2(&labels, &skewed));
    assert!(chi_near < 200.0, "alpha=100 chi-square {chi_near} not near-uniform");
    assert!(chi_far > 1000.0, "alpha=0.1 chi-square {chi_far} not concentrated");
    assert!(
        chi_far > 10.0 * chi_near,
        "concentration gap too small: {chi_far} vs {chi_near}"
    );
    // the marginal view of the same fact: top-label share ~1/43 at α=100,
    // dominated by whole classes at α=0.1
    let share_near = mean_top_label_share(&labels, &near_iid);
    let share_far = mean_top_label_share(&labels, &skewed);
    assert!(share_near < 0.06, "alpha=100 top-label share {share_near}");
    assert!(share_far > 0.15, "alpha=0.1 top-label share {share_far}");
}

#[test]
fn zipf_skew_gives_a_heavy_sample_count_tail() {
    // α=50 keeps per-class proportions close to the Zipf weights, so the
    // realized shard sizes follow (i+1)^-1.2: strictly front-loaded, with
    // the head more than twice the tail — while still covering every
    // sample exactly once
    let labels = balanced_labels(NUM_CLASSES * 100);
    let mut rng = Rng::seed_from(11).stream("zipf");
    let recipe = dirichlet_recipe(&labels, 8, 50.0, 1.2, 8, &mut rng).unwrap();
    let sizes: Vec<usize> = (0..8).map(|c| recipe.shard_of(c).len()).collect();
    assert_eq!(sizes.iter().sum::<usize>(), labels.len());
    assert!(sizes[0] > 2 * sizes[7], "no heavy tail: {sizes:?}");
    assert!(sizes[0] > sizes[3] && sizes[3] > sizes[7], "not front-loaded: {sizes:?}");
}

#[test]
fn partition_is_exact_and_seed_deterministic() {
    let labels = balanced_labels(860);
    let mk = || {
        let mut rng = Rng::seed_from(21).stream("cover");
        dirichlet_recipe(&labels, 6, 0.3, 0.5, 8, &mut rng).unwrap()
    };
    let a = mk();
    // same seed, same recipe — offsets and order byte for byte
    assert_eq!(a, mk());
    // every sample assigned exactly once
    let mut all: Vec<usize> = (0..a.clients()).flat_map(|c| a.shard_of(c).iter().copied()).collect();
    all.sort_unstable();
    assert_eq!(all, (0..labels.len()).collect::<Vec<_>>());
    // a different seed reshuffles (the partition is rng-driven, not fixed)
    let mut rng = Rng::seed_from(22).stream("cover");
    let b = dirichlet_recipe(&labels, 6, 0.3, 0.5, 8, &mut rng).unwrap();
    assert_ne!(a, b);
}

/// Shared fixture for the full-FL convergence runs: 6 clients, the
/// default 16,8,4 ladder, GradStatsBackend over the mock manifest.
fn conv_cfg(dir: &std::path::Path) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = dir.to_path_buf();
    cfg.variant = "mock".into();
    cfg.clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = 10;
    cfg.train_samples = 384;
    cfg.test_samples = 32;
    cfg.scheme = Scheme::parse("16,8,4").unwrap();
    cfg
}

fn run_final_loss(cfg: RunConfig, rt: &Rc<Runtime>) -> f64 {
    let mut exp = Experiment::builder(cfg)
        .runtime(rt.clone())
        .backend_boxed(Box::new(GradStatsBackend::for_mock()))
        .build()
        .unwrap();
    exp.run().unwrap().final_loss
}

#[test]
fn full_fl_dirichlet_runs_are_deterministic_across_threads() {
    // the recipe is drawn from the run's own "shard" stream, so the whole
    // trajectory — partition included — reproduces per seed, and the
    // kernel-chunking axis never leaks into it
    let dir = mock_artifacts_dir("noniid_det");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |threads: usize| {
        let mut cfg = conv_cfg(&dir);
        cfg.rounds = 3;
        cfg.partition = PartitionKind::Dirichlet;
        cfg.alpha = 0.2;
        cfg.skew_zipf = 0.6;
        cfg.threads = threads;
        let mut exp = Experiment::builder(cfg)
            .runtime(rt.clone())
            .backend_boxed(Box::new(GradStatsBackend::for_mock()))
            .build()
            .unwrap();
        let report = exp.run().unwrap();
        let bits: Vec<u32> = exp.global_model().iter().map(|v| v.to_bits()).collect();
        (bits, report.final_loss.to_bits(), report.final_accuracy.to_bits())
    };
    let once = mk(1);
    assert_eq!(once, mk(1), "same seed, same trajectory");
    assert_eq!(once, mk(4), "threads must not change the trajectory");
}

#[test]
fn convergence_orders_iid_before_mild_before_severe_skew() {
    // THE convergence-science pin: final distance-to-optimum loss under
    // the noise-free oracle aggregator, averaged over 8 seeds, orders
    // IID < Dirichlet(1.0) < Dirichlet(0.1).  GradStatsBackend makes the
    // mechanism explicit — skewed shards pull toward persistently
    // displaced optima whose unweighted fleet mean no longer cancels —
    // and the ordering is a property of the partition, not of a lucky
    // seed.
    let dir = mock_artifacts_dir("noniid_conv");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let seeds: Vec<u64> = (0..8).collect();
    let mean_loss = |partition: PartitionKind, alpha: f64| -> f64 {
        let losses: Vec<f64> = seeds
            .iter()
            .map(|&s| {
                let mut cfg = conv_cfg(&dir);
                cfg.partition = partition;
                cfg.alpha = alpha;
                cfg.aggregation = Aggregation::Ideal;
                cfg.seed = s;
                run_final_loss(cfg, &rt)
            })
            .collect();
        mean(&losses)
    };
    let iid = mean_loss(PartitionKind::Iid, 0.5);
    let mild = mean_loss(PartitionKind::Dirichlet, 1.0);
    let severe = mean_loss(PartitionKind::Dirichlet, 0.1);
    assert!(
        iid < mild,
        "IID ({iid:.6}) should out-converge Dirichlet(1.0) ({mild:.6})"
    );
    assert!(
        mild < severe,
        "Dirichlet(1.0) ({mild:.6}) should out-converge Dirichlet(0.1) ({severe:.6})"
    );
}

#[test]
fn aggregation_noise_slows_convergence_for_every_partition() {
    // analog OTA at 0 dB injects real receiver noise into the aggregated
    // update; relative to the noise-free oracle it must cost final loss
    // under BOTH partitions (noise is orthogonal to heterogeneity)
    let dir = mock_artifacts_dir("noniid_noise");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let seeds: Vec<u64> = (0..8).collect();
    let mean_loss = |partition: PartitionKind, agg: Aggregation| -> f64 {
        let losses: Vec<f64> = seeds
            .iter()
            .map(|&s| {
                let mut cfg = conv_cfg(&dir);
                cfg.partition = partition;
                cfg.alpha = 0.3;
                cfg.aggregation = agg;
                cfg.channel.snr_db = 0.0;
                cfg.seed = s;
                run_final_loss(cfg, &rt)
            })
            .collect();
        mean(&losses)
    };
    for partition in [PartitionKind::Iid, PartitionKind::Dirichlet] {
        let ideal = mean_loss(partition, Aggregation::Ideal);
        let noisy = mean_loss(partition, Aggregation::OtaAnalog);
        assert!(
            noisy > ideal,
            "{partition}: noisy OTA ({noisy:.6}) should trail the oracle ({ideal:.6})"
        );
    }
}

#[test]
fn dirichlet_runs_use_the_generated_corpus_labels() {
    // end-to-end sanity: the coordinator hands the REAL generated corpus
    // labels (not the balanced synthetic ones above) to the partitioner,
    // and the resulting lazy-fleet shards are exactly the recipe's —
    // reproduce the recipe from the same stream discipline and compare
    let dir = mock_artifacts_dir("noniid_corpus");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mut cfg = conv_cfg(&dir);
    cfg.rounds = 1;
    cfg.partition = PartitionKind::Dirichlet;
    cfg.alpha = 0.3;
    let seed = cfg.seed;
    let (train_samples, train_batch) = (cfg.train_samples, 8usize);
    let mut exp = Experiment::builder(cfg)
        .runtime(rt.clone())
        .backend_boxed(Box::new(GradStatsBackend::for_mock()))
        .build()
        .unwrap();
    exp.run().unwrap();
    // the coordinator's stream discipline: root -> "data" (train corpus
    // first) -> "shard" (partition)
    let root = Rng::seed_from(seed);
    let mut data_rng = root.stream("data");
    let train = Dataset::generate(train_samples, &mut data_rng);
    let mut shard_rng = root.stream("shard");
    let recipe =
        dirichlet_recipe(&train.labels, 6, 0.3, 0.0, train_batch, &mut shard_rng).unwrap();
    let shards: Vec<Vec<usize>> = (0..6)
        .map(|c| exp.coordinator().client_shard(c).to_vec())
        .collect();
    for (c, shard) in shards.iter().enumerate() {
        assert_eq!(shard.as_slice(), recipe.shard_of(c), "client {c} shard");
        assert!(shard.len() >= train_batch, "client {c} under one batch");
    }
}
