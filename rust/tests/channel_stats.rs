//! Statistical property suite for the channel-realism subsystem AND the
//! massive-fleet sampling layer.
//!
//! These tests pin the DISTRIBUTIONAL claims the models and samplers
//! make, not just their plumbing:
//!
//! * [`GaussMarkov`] draws have empirical lag-1 autocorrelation ≈ ρ and
//!   stay unit power (the AR(1) innovation scaling is correct);
//! * [`PathLossGeometry`] mean SNR decays monotonically with distance
//!   (and the empirical received power tracks the site gains);
//! * [`RayleighPilot`] magnitudes pass a Kolmogorov–Smirnov-style bound
//!   against the Rayleigh CDF `F(x) = 1 - exp(-x²)` (unit-power, σ=1/√2);
//! * `Selection::SampledK` (Floyd's algorithm) selects each client with
//!   equal frequency — a chi-square uniformity bound over ≥ 20k rounds;
//! * persistent channel state follows the CLIENT IDENTITY through random
//!   selection, never the participant slot: under `SampledK` a far
//!   [`PathLossGeometry`] client stays persistently weak (its empirical
//!   power matches its OWN site gain), and each [`GaussMarkov`] client's
//!   lag-1 autocorrelation matches its OWN ρ — both fail on slot-keyed
//!   state, which averages every client toward the fleet mean;
//! * a 1,000,000-client fleet's sharded round loop materializes only
//!   O(K + shard·n) state — asserted with a per-THREAD counting
//!   allocator (a fleet-sized `Vec` of anything would blow the byte
//!   budget by 10×), and zero allocations once warm — including the
//!   id-keyed stateful-channel path (bounded LRU, capacity 2·K).
//!
//! Everything is seeded, so each test is deterministic: the tolerances
//! are several standard errors wide at these sample sizes, and a seed
//! that passes once passes forever.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use mpota::channel::{geometry, ChannelConfig, FadingKind, RoundChannel, C32};
use mpota::fl::{Scheme, Selection};
use mpota::kernels::PayloadPlane;
use mpota::quant;
use mpota::rng::Rng;
use mpota::sim::{
    AnalogOta, ChannelModel, GaussMarkov, PathLossGeometry, PolicyCtx,
    PrecisionPolicy, RayleighPilot, Session, StaticScheme,
};

// ---------------------------------------------------------------------
// Per-thread counting allocator: only the thread that opted in (via
// `TRACKING`) is counted, so the massive-fleet memory test is immune to
// the other tests in this binary running concurrently on their threads.
// const-initialized TLS cells never allocate on access (no lazy init),
// and `try_with` guards TLS teardown.
// ---------------------------------------------------------------------

thread_local! {
    static TRACKING: Cell<bool> = const { Cell::new(false) };
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static THREAD_BYTES: Cell<u64> = const { Cell::new(0) };
}

struct ThreadCountingAlloc;

impl ThreadCountingAlloc {
    fn record(bytes: usize) {
        let _ = TRACKING.try_with(|t| {
            if t.get() {
                let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
                let _ = THREAD_BYTES.try_with(|c| c.set(c.get() + bytes as u64));
            }
        });
    }
}

// SAFETY: every method delegates to `System` verbatim — the only addition
// is a thread-local count — so System's GlobalAlloc contract carries over.
unsafe impl GlobalAlloc for ThreadCountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ThreadCountingAlloc::record(layout.size());
        System.alloc(layout)
    }

    // SAFETY: forwarded to `System` unchanged.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: forwarded to `System` unchanged (plus the count).
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ThreadCountingAlloc::record(new_size);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: ThreadCountingAlloc = ThreadCountingAlloc;

/// Drive `model` for `rounds` rounds of `clients` and return the pooled
/// (lag-1 autocorrelation, mean power) of the true channel coefficients.
fn channel_stats(
    model: &mut dyn ChannelModel,
    clients: usize,
    rounds: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::seed_from(seed);
    let mut rc = RoundChannel::empty();
    let mut prev: Vec<C32> = Vec::new();
    let (mut num, mut den_pairs) = (0.0f64, 0.0f64);
    let mut power = 0.0f64;
    for t in 0..rounds {
        model.draw_into(clients, &mut rng, &mut rc);
        for (k, c) in rc.clients.iter().enumerate() {
            power += c.h.norm_sq() as f64;
            if t > 0 {
                let p = prev[k];
                // Re(h(t) · h*(t-1))
                num += (c.h.re * p.re + c.h.im * p.im) as f64;
                den_pairs += p.norm_sq() as f64;
            }
        }
        prev.clear();
        prev.extend(rc.clients.iter().map(|c| c.h));
    }
    (num / den_pairs, power / (rounds * clients) as f64)
}

#[test]
fn gauss_markov_lag1_autocorrelation_matches_rho() {
    for rho in [0.0f32, 0.3, 0.7, 0.95] {
        let mut cfg = ChannelConfig::default();
        cfg.perfect_csi = true; // skip pilot noise: h statistics unchanged
        cfg.rho = rho;
        let mut model = GaussMarkov::new(cfg);
        let seed = 1000 + (rho * 100.0) as u64; // distinct stream per rho
        let (acf, power) = channel_stats(&mut model, 4, 12_000, seed);
        assert!(
            (acf - rho as f64).abs() < 0.03,
            "rho={rho}: empirical lag-1 autocorrelation {acf}"
        );
        assert!(
            (power - 1.0).abs() < 0.05,
            "rho={rho}: E|h|^2 = {power} (marginal must stay CN(0,1))"
        );
    }
}

#[test]
fn iid_rayleigh_has_no_round_memory() {
    let mut cfg = ChannelConfig::default();
    cfg.perfect_csi = true;
    let mut model = RayleighPilot::new(cfg);
    let (acf, power) = channel_stats(&mut model, 4, 12_000, 2000);
    assert!(acf.abs() < 0.02, "i.i.d. model shows autocorrelation {acf}");
    assert!((power - 1.0).abs() < 0.05, "E|h|^2 = {power}");
}

#[test]
fn gauss_markov_heterogeneous_rhos_are_per_client() {
    // two clients with very different mobility in one fleet: each track
    // shows its own autocorrelation
    let mut cfg = ChannelConfig::default();
    cfg.perfect_csi = true;
    let rhos = [0.1f32, 0.9];
    let mut model = GaussMarkov::with_rhos(cfg, rhos.to_vec());
    let mut rng = Rng::seed_from(3000);
    let mut rc = RoundChannel::empty();
    let rounds = 20_000;
    let mut prev = [C32::ZERO; 2];
    let mut num = [0.0f64; 2];
    let mut den = [0.0f64; 2];
    for t in 0..rounds {
        model.draw_into(2, &mut rng, &mut rc);
        for k in 0..2 {
            let h = rc.clients[k].h;
            if t > 0 {
                num[k] += (h.re * prev[k].re + h.im * prev[k].im) as f64;
                den[k] += prev[k].norm_sq() as f64;
            }
            prev[k] = h;
        }
    }
    for k in 0..2 {
        let acf = num[k] / den[k];
        assert!(
            (acf - rhos[k] as f64).abs() < 0.03,
            "client {k}: acf {acf} vs rho {}",
            rhos[k]
        );
    }
}

#[test]
fn path_loss_mean_snr_decays_monotonically_with_distance() {
    let mut cfg = ChannelConfig::default();
    cfg.model = FadingKind::PathLoss;
    cfg.shadowing_db = 0.0; // isolate the distance trend
    cfg.perfect_csi = true;
    let clients = 15usize;
    let mut model = PathLossGeometry::new(cfg);
    let mut rng = Rng::seed_from(4000);
    let mut rc = RoundChannel::empty();
    model.draw_into(clients, &mut rng, &mut rc);

    // the large-scale gains themselves are strictly monotone in distance
    let mut sites = model.sites().to_vec();
    assert_eq!(sites.len(), clients);
    sites.sort_by(|a, b| a.distance.partial_cmp(&b.distance).unwrap());
    for w in sites.windows(2) {
        assert!(
            w[0].amp > w[1].amp,
            "mean SNR must decay with distance: {:?} vs {:?}",
            w[0],
            w[1]
        );
    }

    // and the empirical received power tracks them: compare the nearest
    // and farthest client over many rounds
    let (mut near, mut far) = (0usize, 0usize);
    for (k, s) in model.sites().iter().enumerate() {
        if s.distance < model.sites()[near].distance {
            near = k;
        }
        if s.distance > model.sites()[far].distance {
            far = k;
        }
    }
    let rounds = 4000;
    let (mut p_near, mut p_far) = (0.0f64, 0.0f64);
    for _ in 0..rounds {
        model.draw_into(clients, &mut rng, &mut rc);
        p_near += rc.clients[near].h.norm_sq() as f64;
        p_far += rc.clients[far].h.norm_sq() as f64;
    }
    let emp_db = 10.0 * (p_near / p_far).log10();
    let amp_n = model.sites()[near].amp as f64;
    let amp_f = model.sites()[far].amp as f64;
    let expect_db = 20.0 * (amp_n / amp_f).log10();
    assert!(
        (emp_db - expect_db).abs() < 1.0,
        "empirical near/far power gap {emp_db:.2} dB vs geometric {expect_db:.2} dB"
    );
}

#[test]
fn path_loss_shadowing_perturbs_the_distance_trend() {
    // residual of the per-site gain around the pure log-distance trend:
    // exactly constant without shadowing, spread out with it
    let residuals = |shadowing_db: f32| -> Vec<f64> {
        let mut cfg = ChannelConfig::default();
        cfg.model = FadingKind::PathLoss;
        cfg.shadowing_db = shadowing_db;
        let mut model = PathLossGeometry::new(cfg.clone());
        let mut rng = Rng::seed_from(5000);
        let mut rc = RoundChannel::empty();
        model.draw_into(30, &mut rng, &mut rc);
        model
            .sites()
            .iter()
            .map(|s| {
                20.0 * (s.amp as f64).log10()
                    - geometry::path_gain_db(s.distance, cfg.path_loss_exp) as f64
            })
            .collect()
    };
    let spread = |xs: &[f64]| {
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
    };
    let flat = residuals(0.0);
    assert!(
        spread(&flat) < 1e-3,
        "no shadowing: residual must be the constant normalization offset"
    );
    let shadowed = residuals(8.0);
    assert!(
        spread(&shadowed) > 3.0,
        "8 dB shadowing: residual std {} too small",
        spread(&shadowed)
    );
}

#[test]
fn rayleigh_pilot_magnitude_passes_ks_bound() {
    // |h| for h ~ CN(0,1) is Rayleigh(1/sqrt 2): F(x) = 1 - exp(-x²)
    let cfg = ChannelConfig { perfect_csi: true, ..Default::default() };
    let mut model = RayleighPilot::new(cfg);
    let mut rng = Rng::seed_from(6000);
    let mut rc = RoundChannel::empty();
    let (clients, rounds) = (15usize, 4000usize);
    let mut mags: Vec<f64> = Vec::with_capacity(clients * rounds);
    for _ in 0..rounds {
        model.draw_into(clients, &mut rng, &mut rc);
        mags.extend(rc.clients.iter().map(|c| c.h.abs() as f64));
    }
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = mags.len() as f64;
    let mut d = 0.0f64;
    for (i, &x) in mags.iter().enumerate() {
        let f = 1.0 - (-x * x).exp();
        let lo = (f - i as f64 / n).abs();
        let hi = ((i + 1) as f64 / n - f).abs();
        d = d.max(lo).max(hi);
    }
    // KS critical value at alpha=0.01 is ~1.63/sqrt(n) ≈ 0.0067 for
    // n = 60k; the fixed seed makes the wider bound deterministic anyway
    assert!(d < 0.01, "KS statistic {d} against the Rayleigh CDF");
}

#[test]
fn gauss_markov_trajectories_are_seed_deterministic() {
    let mut cfg = ChannelConfig::default();
    cfg.rho = 0.8;
    let run = |seed: u64| -> Vec<u32> {
        let mut model = GaussMarkov::new(cfg.clone());
        let mut rng = Rng::seed_from(seed);
        let mut rc = RoundChannel::empty();
        let mut bits = Vec::new();
        for _ in 0..20 {
            model.draw_into(6, &mut rng, &mut rc);
            bits.extend(rc.clients.iter().map(|c| c.h.re.to_bits()));
        }
        bits
    };
    assert_eq!(run(42), run(42), "same seed must give identical trajectories");
    assert_ne!(run(42), run(43), "different seeds must differ");
}

#[test]
fn sampled_k_selection_frequency_is_uniform() {
    // Floyd's sampling must select every client with equal probability:
    // chi-square over a 40-client fleet, K=4 per round, 25k rounds
    // (100k draws, expected 2500 per client).  df = 39: mean 39, std
    // ~8.8 — the 80 bound is ~4.6σ (p < 1e-4), and the fixed seed makes
    // the statistic a constant anyway.
    let n = 40usize;
    let k = 4usize;
    let rounds = 25_000usize;
    let sel = Selection::SampledK(k);
    let mut rng = Rng::seed_from(7777);
    let mut counts = vec![0u64; n];
    let mut out = Vec::new();
    for t in 1..=rounds {
        sel.select_into(n, t, &mut rng, &mut out);
        assert_eq!(out.len(), k);
        assert!(out.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
        for &c in &out {
            counts[c] += 1;
        }
    }
    let expected = (rounds * k) as f64 / n as f64;
    let chi2: f64 = counts
        .iter()
        .map(|&c| {
            let d = c as f64 - expected;
            d * d / expected
        })
        .sum();
    assert!(chi2 < 80.0, "chi-square {chi2:.2} over {n} cells (exp {expected})");
    // and no client is starved or doubled
    for (c, &cnt) in counts.iter().enumerate() {
        assert!(
            (cnt as f64) > 0.7 * expected && (cnt as f64) < 1.3 * expected,
            "client {c} selected {cnt} times (expected ~{expected})"
        );
    }
}

#[test]
fn path_loss_far_client_stays_weak_under_sampled_k() {
    // THE slot-aliasing regression: persistent channel state must follow
    // the client IDENTITY, not the participant slot.  Slot-keyed geometry
    // hands site k to whichever client lands in slot k this round, so
    // under random selection every client's long-run received power
    // averages over ALL sites and the fleet looks artificially
    // homogeneous.  Id-keyed geometry keeps a far client persistently
    // weak, whichever slot it occupies.
    //
    // Drive PathLossGeometry with SampledK(8) of 16 for 500 rounds and
    // check every client's empirical mean power against its OWN site
    // power gain.  |h|²/amp² is Exp(1) per observation and each client is
    // observed ~250 times (SE ≤ 0.082 at the ≥ 150 floor we assert), so
    // the [0.5, 1.6] ratio window is > 6 standard errors wide — while
    // under slot keying the extreme-site clients' ratios collapse toward
    // 1/gain², far outside the window for any cohort with ≥ 4× gain
    // spread (the default α = 3 over a 10..100 m disc plus 6 dB
    // shadowing gives much more).
    let n = 16usize;
    let k = 8usize;
    let rounds = 500usize;
    let mut cfg = ChannelConfig::default();
    cfg.model = FadingKind::PathLoss;
    cfg.perfect_csi = true;
    let mut model = PathLossGeometry::new(cfg);
    let sel = Selection::SampledK(k);
    let mut sel_rng = Rng::seed_from(8100);
    let mut ch_rng = Rng::seed_from(8200);
    let mut rc = RoundChannel::empty();
    let mut selected: Vec<usize> = Vec::new();
    let mut pow = vec![0.0f64; n];
    let mut obs = vec![0u64; n];
    for t in 1..=rounds {
        sel.select_into(n, t, &mut sel_rng, &mut selected);
        model.draw_for(&selected, &mut ch_rng, &mut rc);
        for (slot, &id) in selected.iter().enumerate() {
            pow[id] += rc.clients[slot].h.norm_sq() as f64;
            obs[id] += 1;
        }
    }
    // capacity 2·K = 16 = N: nobody is ever evicted, every site resident
    let mut gain_lo = (f64::INFINITY, 0usize);
    let mut gain_hi = (0.0f64, 0usize);
    let mut emp = vec![0.0f64; n];
    for id in 0..n {
        assert!(obs[id] >= 150, "client {id} observed only {} times", obs[id]);
        let amp = model.site_for(id).expect("capacity 2K keeps N=16 resident").amp
            as f64;
        let gain = amp * amp;
        emp[id] = pow[id] / obs[id] as f64;
        let ratio = emp[id] / gain;
        assert!(
            (0.5..=1.6).contains(&ratio),
            "client {id}: empirical power {:.3} vs own site gain² {gain:.3} \
             (ratio {ratio:.2}) — channel state slot-aliased?",
            emp[id]
        );
        if gain < gain_lo.0 {
            gain_lo = (gain, id);
        }
        if gain > gain_hi.0 {
            gain_hi = (gain, id);
        }
    }
    // the geometry really is asymmetric at this seed…
    let geo_spread = gain_hi.0 / gain_lo.0;
    assert!(geo_spread > 4.0, "site gain spread {geo_spread:.2} too flat");
    // …and the EMPIRICAL spread tracks it: the far client's received
    // power stays persistently below the near client's by (almost) the
    // full geometric ratio — slot-keyed state would flatten this to ~1.
    let emp_spread = emp[gain_hi.1] / emp[gain_lo.1];
    assert!(
        emp_spread > geo_spread * 0.3,
        "near/far empirical spread {emp_spread:.2} vs geometric \
         {geo_spread:.2} — far client not persistently weak?"
    );
}

#[test]
fn gauss_markov_acf_follows_client_id_under_sampled_k() {
    // Companion slot-aliasing regression for the TIME axis: each selected
    // client advances its OWN AR(1) chain by one step per participation,
    // so the lag-1 autocorrelation over one client's consecutive
    // observations is that client's ρ — whichever slots it occupied.
    // Slot-keyed state splices different clients' chains together and
    // drags every per-client ACF toward a selection-averaged value.
    //
    // SampledK(4) of 8 for 6000 rounds: each client is observed ~3000
    // times (~3000 consecutive pairs; we assert ≥ 2000), so the ratio
    // estimator's standard error is ≤ √((1−ρ²)/2000) ≤ 0.023 and the
    // 0.1 tolerance is > 4σ — while the per-client ρs below span
    // 0.05..0.9, far more than 0.1 apart.
    let n = 8usize;
    let k = 4usize;
    let rounds = 6000usize;
    let rhos = vec![0.05f32, 0.9, 0.3, 0.7, 0.15, 0.8, 0.45, 0.6];
    let mut cfg = ChannelConfig::default();
    cfg.perfect_csi = true;
    let mut model = GaussMarkov::with_rhos(cfg, rhos.clone());
    let sel = Selection::SampledK(k);
    let mut sel_rng = Rng::seed_from(8300);
    let mut ch_rng = Rng::seed_from(8400);
    let mut rc = RoundChannel::empty();
    let mut selected: Vec<usize> = Vec::new();
    let mut prev: Vec<Option<C32>> = vec![None; n];
    let mut num = vec![0.0f64; n];
    let mut den = vec![0.0f64; n];
    let mut pairs = vec![0u64; n];
    for t in 1..=rounds {
        sel.select_into(n, t, &mut sel_rng, &mut selected);
        model.draw_for(&selected, &mut ch_rng, &mut rc);
        for (slot, &id) in selected.iter().enumerate() {
            let h = rc.clients[slot].h;
            if let Some(p) = prev[id] {
                // Re(h(t)·h*(t_prev)) over |h(t_prev)|²: conditional on
                // the previous observation, E[Re(h·p*)] = ρ·|p|²
                num[id] += (h.re * p.re + h.im * p.im) as f64;
                den[id] += p.norm_sq() as f64;
                pairs[id] += 1;
            }
            prev[id] = Some(h);
        }
    }
    for id in 0..n {
        assert!(pairs[id] >= 2000, "client {id}: only {} pairs", pairs[id]);
        let acf = num[id] / den[id];
        assert!(
            (acf - rhos[id] as f64).abs() < 0.1,
            "client {id}: lag-1 ACF {acf:.3} vs own rho {} — \
             AR(1) state slot-aliased?",
            rhos[id]
        );
    }
}

#[test]
fn million_client_fleet_round_state_is_o_shard_not_o_fleet() {
    // A full sharded channel-only round loop over a 1,000,000-client
    // fleet: SampledK selection (O(K) state), per-participant policy
    // assignment (O(K)), 16-row payload shards streamed into the air
    // accumulator (O(shard·n)).  The per-thread counting allocator
    // proves (a) the COLD START — construction + first rounds, every
    // buffer growing to capacity — stays under 1 MB total, an order of
    // magnitude below what any fleet-sized vector would cost (1M × 8-byte
    // indices = 8 MB; even 1M × 1-byte levels = 1 MB), and (b) warm
    // rounds allocate NOTHING.
    const FLEET: usize = 1_000_000;
    const KSEL: usize = 64;
    const SHARD: usize = 16;
    const N: usize = 2048;

    TRACKING.with(|t| t.set(true));
    let base_allocs = THREAD_ALLOCS.with(|c| c.get());
    let base_bytes = THREAD_BYTES.with(|c| c.get());

    let root = Rng::seed_from(9000);
    let mut select_rng = root.stream("select");
    let mut payload_rng = root.stream("payload");
    let mut session = Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        Box::new(AnalogOta),
        root.stream("channel"),
        root.stream("noise"),
        1,
    );
    let mut policy = StaticScheme::new(Scheme::parse("16,8").unwrap());
    let selection = Selection::SampledK(KSEL);
    let mut selected: Vec<usize> = Vec::new();
    let mut assigned = Vec::new();
    let mut plane = PayloadPlane::new();

    let round = |t: usize,
                 session: &mut Session,
                 select_rng: &mut Rng,
                 payload_rng: &mut Rng,
                 policy: &mut StaticScheme,
                 selected: &mut Vec<usize>,
                 assigned: &mut Vec<mpota::quant::Precision>,
                 plane: &mut PayloadPlane| {
        selection.select_into(FLEET, t, select_rng, selected);
        let kk = selected.len();
        policy
            .assign_selected_into(
                &PolicyCtx { round: t, clients: FLEET, snr_db: 20.0, prev: None },
                &selected[..],
                assigned,
            )
            .unwrap();
        session.begin_aggregate(t, kk, N);
        let mut lo = 0usize;
        while lo < kk {
            let hi = (lo + SHARD).min(kk);
            plane.reset(hi - lo, N);
            for r in 0..(hi - lo) {
                let row = plane.row_mut(r);
                payload_rng.fill_normal(row, 0.0, 1.0);
                quant::fake_quant_inplace(row, assigned[lo + r]);
            }
            session.accumulate_shard(plane, lo, &assigned[lo..hi]);
            lo = hi;
        }
        let stats = session.finalize_aggregate(t, &assigned[..]);
        assert!(stats.participants <= KSEL);
        std::hint::black_box(stats.participants);
    };

    // cold start: build + grow every buffer over three rounds
    for t in 1..=3 {
        round(
            t,
            &mut session,
            &mut select_rng,
            &mut payload_rng,
            &mut policy,
            &mut selected,
            &mut assigned,
            &mut plane,
        );
    }
    let cold_bytes = THREAD_BYTES.with(|c| c.get()) - base_bytes;
    let cold_allocs = THREAD_ALLOCS.with(|c| c.get()) - base_allocs;
    assert!(
        cold_bytes < 1 << 20,
        "cold start allocated {cold_bytes} bytes over {cold_allocs} allocations \
         — fleet-sized state materialized?"
    );

    // warm rounds: the steady-state loop allocates nothing at all
    let warm_before = THREAD_ALLOCS.with(|c| c.get());
    for t in 4..=24 {
        round(
            t,
            &mut session,
            &mut select_rng,
            &mut payload_rng,
            &mut policy,
            &mut selected,
            &mut assigned,
            &mut plane,
        );
    }
    let warm = THREAD_ALLOCS.with(|c| c.get()) - warm_before;
    TRACKING.with(|t| t.set(false));
    assert_eq!(
        warm, 0,
        "steady-state 1M-fleet sharded rounds allocated {warm} times"
    );
}

#[test]
fn million_client_fleet_id_keyed_channel_state_is_o_k() {
    // The id-keyed sibling of the test above: a STATEFUL channel model
    // (GaussMarkov, per-client AR(1) memory) driven through the
    // identity-aware `begin_aggregate_partial_for` entry over a
    // 1,000,000-client fleet.  The model's per-client state lives in a
    // bounded id-keyed LRU of capacity 2·K = 128 — so (a) the cold start
    // stays under 1 MB (fleet-keyed state would need megabytes for 1M
    // clients), and (b) warm rounds allocate NOTHING even though every
    // round materializes ~K never-seen client ids: at capacity the LRU
    // recycles the least-recently-used slot in place.
    const FLEET: usize = 1_000_000;
    const KSEL: usize = 64;
    const SHARD: usize = 16;
    const N: usize = 2048;

    TRACKING.with(|t| t.set(true));
    let base_allocs = THREAD_ALLOCS.with(|c| c.get());
    let base_bytes = THREAD_BYTES.with(|c| c.get());

    let root = Rng::seed_from(9100);
    let mut select_rng = root.stream("select");
    let mut payload_rng = root.stream("payload");
    let mut cfg = ChannelConfig::default();
    cfg.rho = 0.9;
    let mut session = Session::new(
        Box::new(GaussMarkov::new(cfg)),
        Box::new(AnalogOta),
        root.stream("channel"),
        root.stream("noise"),
        1,
    );
    let mut policy = StaticScheme::new(Scheme::parse("16,8").unwrap());
    let selection = Selection::SampledK(KSEL);
    let mut selected: Vec<usize> = Vec::new();
    let mut assigned = Vec::new();
    let mut plane = PayloadPlane::new();

    let mut round = |t: usize| {
        selection.select_into(FLEET, t, &mut select_rng, &mut selected);
        let kk = selected.len();
        policy
            .assign_selected_into(
                &PolicyCtx { round: t, clients: FLEET, snr_db: 20.0, prev: None },
                &selected[..],
                &mut assigned,
            )
            .unwrap();
        session.begin_aggregate_partial_for(t, &selected, kk, N);
        let mut lo = 0usize;
        while lo < kk {
            let hi = (lo + SHARD).min(kk);
            plane.reset(hi - lo, N);
            for r in 0..(hi - lo) {
                let row = plane.row_mut(r);
                payload_rng.fill_normal(row, 0.0, 1.0);
                quant::fake_quant_inplace(row, assigned[lo + r]);
            }
            session.accumulate_shard(&plane, lo, &assigned[lo..hi]);
            lo = hi;
        }
        let stats = session.finalize_aggregate(t, &assigned[..]);
        assert!(stats.participants <= KSEL);
        std::hint::black_box(stats.participants);
    };

    // cold start: LRU + every buffer grows to capacity
    for t in 1..=3 {
        round(t);
    }
    let cold_bytes = THREAD_BYTES.with(|c| c.get()) - base_bytes;
    let cold_allocs = THREAD_ALLOCS.with(|c| c.get()) - base_allocs;
    assert!(
        cold_bytes < 1 << 20,
        "cold start allocated {cold_bytes} bytes over {cold_allocs} allocations \
         — fleet-keyed channel state materialized?"
    );

    // warm rounds: fresh ids keep arriving (64-of-1M reselection is
    // vanishingly unlikely), yet the in-place LRU recycling keeps the
    // loop allocation-free
    let warm_before = THREAD_ALLOCS.with(|c| c.get());
    for t in 4..=24 {
        round(t);
    }
    let warm = THREAD_ALLOCS.with(|c| c.get()) - warm_before;
    TRACKING.with(|t| t.set(false));
    assert_eq!(
        warm, 0,
        "steady-state id-keyed stateful-channel rounds allocated {warm} times"
    );
}

#[test]
fn path_loss_geometry_is_seed_deterministic() {
    let mut cfg = ChannelConfig::default();
    cfg.model = FadingKind::PathLoss;
    let place = |seed: u64| -> Vec<(u32, u32)> {
        let mut model = PathLossGeometry::new(cfg.clone());
        let mut rng = Rng::seed_from(seed);
        let mut rc = RoundChannel::empty();
        model.draw_into(10, &mut rng, &mut rc);
        model
            .sites()
            .iter()
            .map(|s| (s.distance.to_bits(), s.amp.to_bits()))
            .collect()
    };
    assert_eq!(place(7), place(7));
    assert_ne!(place(7), place(8));
}
