//! Steady-state allocation audit for the server round path — through the
//! composable trait seams.
//!
//! A counting global allocator wraps the system allocator; after a warmup
//! pass that grows every scratch buffer to capacity, the full server-side
//! round path (policy assignment → selection → channel draw → analog /
//! digital / ideal aggregation → observer dispatch → global-model update)
//! must perform ZERO heap allocations — including the dynamic dispatch
//! through `Box<dyn Aggregator>`, `Box<dyn ChannelModel>`,
//! `Box<dyn PrecisionPolicy>` and `Box<dyn RoundObserver>`.
//!
//! The channel-realism parts are pinned through the same window: the
//! STATEFUL channel models (`GaussMarkov` AR(1) memory, `PathLossGeometry`
//! site table) build their state on the warmup rounds and must then mutate
//! it in place, and the feedback policies (`LossPlateau`, `EnergyBudget`)
//! must react to the previous round's record with scalar state only.
//!
//! Scope: this is the post-training half of `Coordinator::round()` — the
//! client PJRT dispatch (`Runtime::train_step`) allocates literals inside
//! the runtime and is explicitly outside the arena contract (and cannot
//! run without artifacts anyway).
//!
//! PARALLEL phases: since the kernels moved onto the persistent
//! [`mpota::exec`] pool, the `threads > 1` aggregation path and the
//! `workers > 1` client-partition path are ALSO zero-alloc in steady
//! state — pool workers spawn once during warmup and park between jobs;
//! a dispatch installs a stack-allocated job descriptor and wakes them.
//! Phases 2 and 3 pin exactly that (the counting allocator is
//! process-global, so allocations on pool worker threads count too).
//!
//! This file intentionally contains a single #[test]: the counter is
//! process-global and other tests running in parallel would pollute it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: every method delegates to `System` verbatim — the only addition
// is a relaxed atomic count — so System's GlobalAlloc contract carries over.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: forwarded to `System` unchanged.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: forwarded to `System` unchanged (plus the count).
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use mpota::channel::ChannelConfig;
use mpota::fl::{Scheme, Selection};
use mpota::kernels::PayloadPlane;
use mpota::metrics::RoundRecord;
use mpota::ota::AggregateStats;
use mpota::quant::{self, Precision, Rounding};
use mpota::rng::Rng;
use mpota::sim::{
    AnalogOta, DeadlineCtx, DeadlinePolicy, DigitalOrthogonal, EnergyBudget,
    GaussMarkov, IdealFedAvg, LossPlateau, PathLossGeometry, PolicyCtx,
    PrecisionPolicy, RayleighPilot, RoundObserver, Session, StaticScheme,
    VirtualClock,
};
use mpota::tensor;

/// Allocation-free observer: bumps plain counters on every hook.  The
/// counters exist to give the hooks real work; only the allocation count
/// is asserted (observer *semantics* are covered in `tests/sim.rs`).
#[derive(Default)]
#[allow(dead_code)]
struct TallyObserver {
    rounds: u64,
    channels: u64,
    aggregates: u64,
}

impl RoundObserver for TallyObserver {
    fn on_round_start(&mut self, _round: usize) {
        self.rounds += 1;
    }
    fn on_channel(&mut self, _round: usize, _channel: &mpota::channel::RoundChannel) {
        self.channels += 1;
    }
    fn on_aggregate(&mut self, _round: usize, _stats: &AggregateStats) {
        self.aggregates += 1;
    }
    fn on_round_end(&mut self, _record: &RoundRecord) {}
}

#[test]
fn steady_state_round_path_is_allocation_free() {
    let k = 9usize;
    let n = 10_000usize;
    let cfg = ChannelConfig::default();
    let layout = mpota::tensor::ParamLayout::from_manifest(
        &mpota::json::parse(r#"[["w", [99, 100]], ["b", [100]]]"#).unwrap(),
    )
    .unwrap();
    assert_eq!(layout.total, n);

    // run-level state (allocated once, like Coordinator::from_parts does)
    let root = Rng::seed_from(42);
    let mut select_rng = root.stream("select");
    let mut theta = vec![0.0f32; n];
    root.stream("init").fill_normal(&mut theta, 0.0, 0.5);

    // the trait-object seams (each an owned Box, like the coordinator's)
    let mut policy: Box<dyn PrecisionPolicy> =
        Box::new(StaticScheme::new(Scheme::parse("16,8,4").unwrap()));
    let mut analog = Session::new(
        Box::new(RayleighPilot::new(cfg.clone())),
        Box::new(AnalogOta),
        root.stream("channel"),
        root.stream("noise"),
        1,
    );
    analog.add_observer(Box::new(TallyObserver::default()));
    let mut digital = Session::new(
        Box::new(RayleighPilot::new(cfg.clone())),
        Box::new(DigitalOrthogonal),
        root.stream("channel-d"),
        root.stream("noise-d"),
        1,
    );
    let mut ideal = Session::new(
        Box::new(RayleighPilot::new(cfg.clone())),
        Box::new(IdealFedAvg),
        root.stream("channel-i"),
        root.stream("noise-i"),
        1,
    );
    // stateful channel models: AR(1) fading memory + path-loss site table
    // are built on the warmup rounds, then mutated in place
    let mut gm_cfg = cfg.clone();
    gm_cfg.rho = 0.9;
    let mut gauss_markov = Session::new(
        Box::new(GaussMarkov::new(gm_cfg)),
        Box::new(AnalogOta),
        root.stream("channel-gm"),
        root.stream("noise-gm"),
        1,
    );
    let mut path_loss = Session::new(
        Box::new(PathLossGeometry::new(cfg)),
        Box::new(AnalogOta),
        root.stream("channel-pl"),
        root.stream("noise-pl"),
        1,
    );
    // feedback policies through Box<dyn>, fed a synthetic previous-round
    // record (scalar fields only — mutating it allocates nothing)
    let mut plateau: Box<dyn PrecisionPolicy> =
        Box::new(LossPlateau::new().with_patience(2));
    let mut energy: Box<dyn PrecisionPolicy> = Box::new(EnergyBudget::new(1.0));
    let mut prev = RoundRecord::default();
    let mut fb_assigned: Vec<Precision> = Vec::new();

    // the coordinator-side round scratch
    let mut assigned: Vec<Precision> = Vec::new();
    let mut selected: Vec<usize> = Vec::new();
    let mut precisions: Vec<Precision> = Vec::new();
    let mut plane = PayloadPlane::new();

    let selection = Selection::UniformK(k);
    let mut round = |t: usize,
                     theta: &mut Vec<f32>,
                     select_rng: &mut Rng,
                     policy: &mut Box<dyn PrecisionPolicy>,
                     analog: &mut Session,
                     digital: &mut Session,
                     ideal: &mut Session| {
        // per-round policy assignment through the trait object
        policy
            .assign_into(
                &PolicyCtx { round: t, clients: k, snr_db: 20.0, prev: None },
                &mut assigned,
            )
            .unwrap();
        // selection + payload build (stand-in for the client loop: fused
        // re-quantize the broadcast model into each plane row)
        selection.select_into(k, t, select_rng, &mut selected);
        plane.reset(selected.len(), n);
        precisions.clear();
        for slot in 0..selected.len() {
            let p = assigned[selected[slot]];
            precisions.push(p);
            quant::fake_quant_layout_into(
                plane.row_mut(slot),
                theta.as_slice(),
                &layout,
                p,
                Rounding::Nearest,
                1,
            );
        }
        // analog OTA path through Session + observers
        analog.begin_round(t);
        let stats = analog.aggregate(t, &plane, &precisions);
        if stats.participants > 0 {
            tensor::axpy_par(theta, 1.0, analog.result(), 1);
        }
        analog.end_round(&RoundRecord::default());
        // digital + ideal baselines over the same plane
        let dstats = digital.aggregate(t, &plane, &precisions);
        assert_eq!(dstats.participants, selected.len());
        let istats = ideal.aggregate(t, &plane, &precisions);
        assert_eq!(istats.participants, selected.len());
        std::hint::black_box((digital.result().len(), ideal.result().len()));
        // stateful channel models over the same plane: AR(1) memory and
        // the path-loss site table must mutate in place
        let gstats = gauss_markov.aggregate(t, &plane, &precisions);
        let pstats = path_loss.aggregate(t, &plane, &precisions);
        std::hint::black_box((gstats.participants, pstats.participants));
        // feedback policies react to the previous round's record
        prev.round = t;
        prev.server_loss = 1.0 / t as f64;
        prev.energy_joules += 0.25;
        prev.evaluated = true;
        let fb_ctx = PolicyCtx { round: t, clients: k, snr_db: 20.0, prev: Some(&prev) };
        plateau.assign_into(&fb_ctx, &mut fb_assigned).unwrap();
        std::hint::black_box(fb_assigned[0]);
        energy.assign_into(&fb_ctx, &mut fb_assigned).unwrap();
        std::hint::black_box(fb_assigned[0]);
    };

    // warmup: two rounds grow every buffer to steady-state capacity
    for t in 1..=2 {
        round(
            t,
            &mut theta,
            &mut select_rng,
            &mut policy,
            &mut analog,
            &mut digital,
            &mut ideal,
        );
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        round(
            t,
            &mut theta,
            &mut select_rng,
            &mut policy,
            &mut analog,
            &mut digital,
            &mut ideal,
        );
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state round path allocated {} times through the trait seams",
        after - before
    );

    // ---- phase 2: threads=4 aggregation through the persistent pool ----
    // superposition chunks the element axis (n=10k → 2 chunks) and the
    // noise fill chunks 2n draws (→ 4 chunks); both dispatch onto the
    // exec pool.  Warmup spawns+parks the workers and grows the scratch;
    // steady state must then allocate NOTHING — on any thread.
    let mut analog4 = Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        Box::new(AnalogOta),
        root.stream("channel-p4"),
        root.stream("noise-p4"),
        4,
    );
    for t in 1..=2 {
        let s = analog4.aggregate(t, &plane, &precisions);
        std::hint::black_box(s.participants);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        let s = analog4.aggregate(t, &plane, &precisions);
        std::hint::black_box(s.participants);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state threads=4 pooled aggregation allocated {} times",
        after - before
    );

    // ---- phase 3: workers=4 client partition writing disjoint rows ----
    // the coordinator's client-phase shape without PJRT: four pool
    // workers each fused-quantize their contiguous rows of the plane
    // (the quantize/modulate half of local_round_into)
    let mut wplane = PayloadPlane::new();
    wplane.reset(8, n);
    let levels = [Precision::of(16), Precision::of(8), Precision::of(4)];
    let theta_ref: &[f32] = &theta;
    let layout_ref = &layout;
    let run_partition = |wplane: &mut PayloadPlane| {
        let rows = wplane.k();
        mpota::kernels::par::par_row_partition_mut(
            4,
            rows,
            wplane.as_mut_slice(),
            |r0, chunk| {
                for (i, row) in chunk.chunks_mut(n).enumerate() {
                    quant::fake_quant_layout_into(
                        row,
                        theta_ref,
                        layout_ref,
                        levels[(r0 + i) % 3],
                        Rounding::Nearest,
                        1,
                    );
                }
            },
        );
    };
    run_partition(&mut wplane);
    run_partition(&mut wplane);
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..6 {
        run_partition(&mut wplane);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state workers=4 client partition allocated {} times",
        after - before
    );

    // ---- phase 4: sharded streaming rounds (shard_size < K, workers=4) ----
    // the massive-fleet round shape end to end: Floyd's SampledK selection
    // (O(K) state), the client quantize/modulate phase row-partitioned
    // across 4 pool workers PER SHARD, each shard folded into the
    // session's persistent air accumulator via the streaming seam, one
    // noise+scale finalize — zero allocation once warm, at threads=4
    let mut sh_session = Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        Box::new(AnalogOta),
        root.stream("channel-sh"),
        root.stream("noise-sh"),
        4,
    );
    let mut sh_select_rng = root.stream("select-sh");
    let sh_selection = Selection::SampledK(6);
    let fleet = 1_000usize;
    let shard = 3usize; // 2 shards of 3 rows: genuinely sharded (< K)
    let mut sh_selected: Vec<usize> = Vec::new();
    let mut sh_plane = PayloadPlane::new();
    let sh_precisions: Vec<Precision> =
        (0..shard).map(|i| levels[i % levels.len()]).collect();
    let sh_round = |t: usize,
                    session: &mut Session,
                    select_rng: &mut Rng,
                    selected: &mut Vec<usize>,
                    plane: &mut PayloadPlane| {
        sh_selection.select_into(fleet, t, select_rng, selected);
        let kk = selected.len();
        session.begin_aggregate(t, kk, n);
        let mut lo = 0usize;
        while lo < kk {
            let hi = (lo + shard).min(kk);
            plane.reset(hi - lo, n);
            mpota::kernels::par::par_row_partition_mut(
                4,
                hi - lo,
                plane.as_mut_slice(),
                |r0, chunk| {
                    for (i, row) in chunk.chunks_mut(n).enumerate() {
                        quant::fake_quant_layout_into(
                            row,
                            theta_ref,
                            layout_ref,
                            levels[(lo + r0 + i) % levels.len()],
                            Rounding::Nearest,
                            1,
                        );
                    }
                },
            );
            session.accumulate_shard(plane, lo, &sh_precisions[..hi - lo]);
            lo = hi;
        }
        let stats = session.finalize_aggregate(t, &sh_precisions);
        std::hint::black_box(stats.participants);
    };
    for t in 1..=2 {
        sh_round(t, &mut sh_session, &mut sh_select_rng, &mut sh_selected, &mut sh_plane);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        sh_round(t, &mut sh_session, &mut sh_select_rng, &mut sh_selected, &mut sh_plane);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state sharded (shard={shard} < K=6, workers=4) rounds \
         allocated {} times",
        after - before
    );

    // ---- phase 5: straggler-masked streaming rounds (PR-6 dropout path) ----
    // the deadline+dropout round shape: VirtualClock exclusion into a
    // reusable mask (fixed 2 RNG draws per slot), partial-participation
    // begin, per-shard masked accumulate skipping excluded rows — all
    // through the same warm buffers
    let mut dl_cfg = mpota::config::RunConfig::default();
    dl_cfg.clients = fleet;
    dl_cfg.deadline_s = 0.019;
    dl_cfg.compute_s = 0.05;
    dl_cfg.latency_jitter = 0.25;
    dl_cfg.slot_s = 0.005;
    dl_cfg.dropout_p = 0.2;
    let mut clock = VirtualClock::new(&dl_cfg); // fleet `down` table: one-time
    let mut straggler_rng = root.stream("straggler-ac");
    let mut dl_session = Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        Box::new(AnalogOta),
        root.stream("channel-dl"),
        root.stream("noise-dl"),
        4,
    );
    let mut dl_select_rng = root.stream("select-dl");
    let dl_selection = Selection::SampledK(6);
    let mut dl_selected: Vec<usize> = Vec::new();
    let mut dl_plane = PayloadPlane::new();
    let mut included: Vec<bool> = Vec::new();
    let dl_precisions: Vec<Precision> =
        (0..6).map(|i| levels[i % levels.len()]).collect();
    let dl_round = |t: usize,
                    clock: &mut VirtualClock,
                    straggler_rng: &mut Rng,
                    session: &mut Session,
                    select_rng: &mut Rng,
                    selected: &mut Vec<usize>,
                    plane: &mut PayloadPlane,
                    included: &mut Vec<bool>| {
        dl_selection.select_into(fleet, t, select_rng, selected);
        let kk = selected.len();
        included.clear();
        included.resize(kk, false);
        clock.exclude_into(
            &DeadlineCtx {
                round: t,
                selected: selected.as_slice(),
                precisions: &dl_precisions[..kk],
            },
            straggler_rng,
            included,
        );
        let mut active_k = 0usize;
        for v in included.iter_mut() {
            *v = !*v; // excluded mask -> inclusion mask, like the coordinator
            active_k += *v as usize;
        }
        session.begin_aggregate_partial(t, kk, active_k, n);
        let mut lo = 0usize;
        while lo < kk {
            let hi = (lo + shard).min(kk);
            plane.reset(hi - lo, n);
            for r in 0..hi - lo {
                if included[lo + r] {
                    quant::fake_quant_layout_into(
                        plane.row_mut(r),
                        theta_ref,
                        layout_ref,
                        dl_precisions[lo + r],
                        Rounding::Nearest,
                        1,
                    );
                }
            }
            session.accumulate_shard_masked(
                plane,
                lo,
                &dl_precisions[lo..hi],
                Some(&included[lo..hi]),
            );
            lo = hi;
        }
        let stats = session.finalize_aggregate(t, &dl_precisions[..kk]);
        std::hint::black_box(stats.participants);
    };
    for t in 1..=2 {
        dl_round(
            t,
            &mut clock,
            &mut straggler_rng,
            &mut dl_session,
            &mut dl_select_rng,
            &mut dl_selected,
            &mut dl_plane,
            &mut included,
        );
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        dl_round(
            t,
            &mut clock,
            &mut straggler_rng,
            &mut dl_session,
            &mut dl_select_rng,
            &mut dl_selected,
            &mut dl_plane,
            &mut included,
        );
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state straggler-masked rounds allocated {} times",
        after - before
    );

    // ---- phase 6: pipelined double-buffered streaming (PR-6 overlap) ----
    // the pipelined round's dispatch shape: one pool broadcast whose task
    // 0 superposes the PREVIOUS super-shard into the session while task 1
    // fills the NEXT plane — both planes and the session warm, nothing
    // allocated per round on any thread
    struct SendMut<T>(*mut T);
    // SAFETY: each pointer is dereferenced by exactly one task of the
    // blocking dispatch below, and the pointee outlives the dispatch.
    unsafe impl<T> Send for SendMut<T> {}
    unsafe impl<T> Sync for SendMut<T> {}

    let pool = mpota::exec::pool();
    let mut pl_session = Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        Box::new(AnalogOta),
        root.stream("channel-pipe"),
        root.stream("noise-pipe"),
        1,
    );
    let mut plane_a = PayloadPlane::new();
    let mut plane_b = PayloadPlane::new();
    let pl_precisions: Vec<Precision> =
        (0..6).map(|i| levels[i % levels.len()]).collect();
    let pl_round = |t: usize,
                    session: &mut Session,
                    pa: &mut PayloadPlane,
                    pb: &mut PayloadPlane| {
        session.begin_aggregate(t, 6, n);
        // first super-shard fills with no overlap partner
        pa.reset(3, n);
        for r in 0..3 {
            quant::fake_quant_layout_into(
                pa.row_mut(r),
                theta_ref,
                layout_ref,
                pl_precisions[r],
                Rounding::Nearest,
                1,
            );
        }
        // overlapped step: superpose rows 0..3 while rows 3..6 fill
        {
            let session_ptr = SendMut(&mut *session as *mut Session);
            let pb_ptr = SendMut(&mut *pb as *mut PayloadPlane);
            let pa_ref: &PayloadPlane = pa;
            let prec = &pl_precisions;
            let task = move |i: usize| {
                if i == 0 {
                    // SAFETY: sole toucher of the session in this dispatch
                    let s = unsafe { &mut *session_ptr.0 };
                    s.accumulate_shard(pa_ref, 0, &prec[0..3]);
                } else {
                    // SAFETY: sole toucher of plane B in this dispatch
                    let p = unsafe { &mut *pb_ptr.0 };
                    p.reset(3, n);
                    for r in 0..3 {
                        quant::fake_quant_layout_into(
                            p.row_mut(r),
                            theta_ref,
                            layout_ref,
                            prec[3 + r],
                            Rounding::Nearest,
                            1,
                        );
                    }
                }
            };
            pool.broadcast(2, &task);
        }
        // drain the last super-shard on the caller
        session.accumulate_shard(pb, 3, &pl_precisions[3..6]);
        let stats = session.finalize_aggregate(t, &pl_precisions);
        std::hint::black_box(stats.participants);
    };
    for t in 1..=2 {
        pl_round(t, &mut pl_session, &mut plane_a, &mut plane_b);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        pl_round(t, &mut pl_session, &mut plane_a, &mut plane_b);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state pipelined double-buffered rounds allocated {} times",
        after - before
    );

    // ---- phase 7: bit-packed streaming shards (PR-9 packed transport) ----
    // the packed round shape: raw client rows are bit-packed into the
    // reusable PackedPlane (packing IS the transmission quantization) and
    // superposed through the fused unpack-fuse kernels at threads=4.  The
    // precisions cover every row representation — raw words (32), masked
    // words (24), top-16 truncation (16/12) and affine code lanes (8/4).
    // Warmup grows the word/meta buffers; steady state allocates nothing.
    let mut pk_session = Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        Box::new(AnalogOta),
        root.stream("channel-pk"),
        root.stream("noise-pk"),
        4,
    );
    assert!(pk_session.supports_packed());
    let mut pk_plane = PayloadPlane::new();
    let mut pk_packed = mpota::kernels::PackedPlane::new();
    let pk_precisions: Vec<Precision> =
        [32u8, 24, 16, 12, 8, 4].iter().map(|&b| Precision::of(b)).collect();
    let pk_round = |t: usize,
                    session: &mut Session,
                    plane: &mut PayloadPlane,
                    packed: &mut mpota::kernels::PackedPlane| {
        session.begin_aggregate(t, 6, n);
        let mut lo = 0usize;
        while lo < 6 {
            let hi = (lo + shard).min(6);
            plane.reset(hi - lo, n);
            for r in 0..hi - lo {
                plane.row_mut(r).copy_from_slice(theta_ref);
            }
            packed.reset(&pk_precisions[lo..hi], n);
            for r in 0..hi - lo {
                packed.pack_row(r, plane.row(r));
            }
            session.accumulate_packed_shard_masked(
                packed,
                lo,
                &pk_precisions[lo..hi],
                None,
            );
            lo = hi;
        }
        let stats = session.finalize_aggregate(t, &pk_precisions);
        std::hint::black_box(stats.participants);
    };
    for t in 1..=2 {
        pk_round(t, &mut pk_session, &mut pk_plane, &mut pk_packed);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        pk_round(t, &mut pk_session, &mut pk_plane, &mut pk_packed);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state packed streaming rounds allocated {} times",
        after - before
    );

    // ---- phase 8: full-FL warm rounds (Dirichlet + GradStatsBackend) ----
    // the convergence suite's round shape END TO END through the real
    // coordinator: Dirichlet CSR shards through the lazy fleet, client
    // SGD via the allocation-free `train_step_into` double buffer, analog
    // OTA aggregation and the per-round evaluation — driven as bare
    // `Coordinator::round` calls (no log pushes), with the client phase
    // on 4 pool workers exactly as a parallel fl-sweep cell runs it.
    // Warmup materializes the 6 clients into the fleet window (capacity
    // 2·K — nothing evicts) and grows every scratch; steady-state rounds
    // must then be heap-silent.
    let fl_dir = mpota::testing::mock_artifacts_dir("alloc_fl");
    let mut fl_cfg = mpota::config::RunConfig::default();
    fl_cfg.artifacts_dir = fl_dir;
    fl_cfg.variant = "mock".into();
    fl_cfg.clients = 6;
    fl_cfg.clients_per_round = 6;
    fl_cfg.rounds = 8;
    fl_cfg.train_samples = 192;
    fl_cfg.test_samples = 32;
    fl_cfg.scheme = Scheme::parse("16,8,4").unwrap();
    fl_cfg.partition = mpota::config::PartitionKind::Dirichlet;
    fl_cfg.alpha = 0.3;
    fl_cfg.skew_zipf = 0.5;
    fl_cfg.workers = 4;
    let fl_runtime =
        std::rc::Rc::new(mpota::runtime::Runtime::load(&fl_cfg.artifacts_dir).unwrap());
    let mut fl_exp = mpota::sim::Experiment::builder(fl_cfg)
        .runtime(fl_runtime)
        .backend_boxed(Box::new(mpota::testing::GradStatsBackend::for_mock()))
        .build()
        .unwrap();
    let coord = fl_exp.coordinator_mut();
    for t in 1..=2 {
        let rec = coord.round(t).unwrap();
        std::hint::black_box(rec.participants);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        let rec = coord.round(t).unwrap();
        std::hint::black_box(rec.participants);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state Dirichlet full-FL rounds allocated {} times",
        after - before
    );
}
