//! Steady-state allocation audit for the server round path.
//!
//! A counting global allocator wraps the system allocator; after a warmup
//! pass that grows every scratch buffer to capacity, the full server-side
//! round path (selection → channel draw → analog/digital/ideal
//! aggregation → global-model update) must perform ZERO heap allocations.
//!
//! Scope: this is the post-training half of `Coordinator::round()` — the
//! client PJRT dispatch (`Runtime::train_step`) allocates literals inside
//! the runtime and is explicitly outside the arena contract (and cannot
//! run without artifacts anyway).  `threads = 1` (the steady-state
//! default): spawning scoped worker threads allocates their stacks, which
//! is the documented cost of opting into `threads > 1`.
//!
//! This file intentionally contains a single #[test]: the counter is
//! process-global and other tests running in parallel would pollute it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use mpota::channel::{pilot, ChannelConfig, RoundChannel};
use mpota::fl::{fedavg, Selection};
use mpota::kernels::PayloadPlane;
use mpota::ota;
use mpota::quant::{self, Precision, Rounding};
use mpota::rng::Rng;
use mpota::tensor;

#[test]
fn steady_state_round_path_is_allocation_free() {
    let k = 8usize;
    let n = 10_000usize;
    let cfg = ChannelConfig::default();
    let layout = mpota::tensor::ParamLayout::from_manifest(
        &mpota::json::parse(r#"[["w", [99, 100]], ["b", [100]]]"#).unwrap(),
    )
    .unwrap();
    assert_eq!(layout.total, n);

    // run-level state (allocated once, like Coordinator::new does)
    let root = Rng::seed_from(42);
    let mut select_rng = root.stream("select");
    let mut channel_rng = root.stream("channel");
    let mut noise_rng = root.stream("noise");
    let mut theta = vec![0.0f32; n];
    root.stream("init").fill_normal(&mut theta, 0.0, 0.5);
    let precisions: Vec<Precision> =
        (0..k).map(|i| Precision::of([16u8, 8, 4][i % 3])).collect();

    // the round scratch arena
    let mut selected: Vec<usize> = Vec::new();
    let mut plane = PayloadPlane::new();
    let mut round_channel = RoundChannel::empty();
    let pilot_seq = pilot::pilot_sequence(cfg.pilot_len);
    let mut ota_scratch = ota::analog::OtaScratch::new();
    let mut agg = Vec::new();

    let selection = Selection::UniformK(k);
    let mut round = |t: usize,
                     theta: &mut Vec<f32>,
                     select_rng: &mut Rng,
                     channel_rng: &mut Rng,
                     noise_rng: &mut Rng| {
        // selection + payload build (stand-in for the client loop: fused
        // re-quantize the broadcast model into each plane row)
        selection.select_into(k, t, select_rng, &mut selected);
        plane.reset(selected.len(), n);
        for slot in 0..selected.len() {
            let p = precisions[selected[slot]];
            quant::fake_quant_layout_into(
                plane.row_mut(slot),
                theta.as_slice(),
                &layout,
                p,
                Rounding::Nearest,
                1,
            );
        }
        // analog OTA path
        round_channel.draw_into(&cfg, selected.len(), channel_rng, &pilot_seq);
        let stats = ota::analog::aggregate_plane_into(
            &plane,
            &round_channel,
            noise_rng,
            &mut ota_scratch,
            1,
        );
        if stats.participants > 0 {
            tensor::axpy_par(theta, 1.0, &ota_scratch.y_re, 1);
        }
        // digital + ideal baselines over the same plane
        let active = &precisions[..selected.len()];
        let dstats = ota::digital::aggregate_plane_into(&plane, active, &mut agg, 1);
        assert_eq!(dstats.participants, selected.len());
        fedavg::mean_plane_into(&plane, &mut agg, 1);
        std::hint::black_box((&agg, stats.participants));
    };

    // warmup: two rounds grow every buffer to steady-state capacity
    for t in 1..=2 {
        round(t, &mut theta, &mut select_rng, &mut channel_rng, &mut noise_rng);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        round(t, &mut theta, &mut select_rng, &mut channel_rng, &mut noise_rng);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state round path allocated {} times",
        after - before
    );
}
