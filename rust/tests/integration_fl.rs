//! End-to-end coordinator integration: Algorithm 1 over all aggregation
//! paths, determinism, energy accounting, and requantization reporting.
//!
//! Kept small (2 rounds, few hundred samples) so the suite stays fast on
//! one core; the full-scale runs live in examples/ and benches/.

use mpota::config::{Aggregation, PolicyKind, RunConfig};
use mpota::coordinator::Coordinator;
use mpota::fl::Scheme;
use mpota::sim::Experiment;

fn artifacts_present() -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature (PJRT execution stubbed)");
        return false;
    }
    let dir = std::path::PathBuf::from(
        std::env::var("MPOTA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let ok = dir.join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
    }
    ok
}

fn tiny_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.rounds = 2;
    cfg.train_samples = 480; // 15 clients x 32 = one batch each
    cfg.test_samples = 96;
    cfg.local_steps = 1;
    cfg.scheme = Scheme::parse("16,8,4").unwrap();
    cfg.eval_every = 1;
    cfg
}

#[test]
fn ota_run_completes_with_report() {
    if !artifacts_present() {
        return;
    }
    let mut coord = Coordinator::new(tiny_cfg()).unwrap();
    let report = coord.run().unwrap();
    assert_eq!(report.log.rounds.len(), 2);
    for rec in &report.log.rounds {
        assert!(rec.participants > 0, "all clients silenced at default SNR");
        assert!(rec.train_loss.is_finite());
        assert!(rec.server_accuracy >= 0.0 && rec.server_accuracy <= 1.0);
    }
    // requant evals exist for every distinct level of the scheme
    assert_eq!(report.requant.len(), 3);
    // energy: mixed scheme must cost less than all-32 counterfactual and
    // more than all-4
    assert!(report.energy.actual_joules < report.energy.all32_joules);
    assert!(report.energy.actual_joules > report.energy.all4_joules);
    assert!(report.energy.saving_vs_32() > 0.0);
}

#[test]
fn all_aggregation_paths_run() {
    if !artifacts_present() {
        return;
    }
    for agg in [Aggregation::OtaAnalog, Aggregation::Digital, Aggregation::Ideal] {
        let mut cfg = tiny_cfg();
        cfg.rounds = 1;
        cfg.aggregation = agg;
        let mut coord = Coordinator::new(cfg).unwrap();
        let report = coord.run().unwrap();
        assert_eq!(report.log.rounds.len(), 1, "{agg}");
        assert!(report.final_loss.is_finite(), "{agg}");
    }
}

#[test]
fn runs_are_deterministic_per_seed() {
    if !artifacts_present() {
        return;
    }
    let run = |seed: u64| {
        let mut cfg = tiny_cfg();
        cfg.rounds = 1;
        cfg.seed = seed;
        let mut coord = Coordinator::new(cfg).unwrap();
        let report = coord.run().unwrap();
        (
            report.final_accuracy,
            report.final_loss,
            report.log.rounds[0].train_loss,
            report.log.rounds[0].ota_mse,
        )
    };
    let a = run(123);
    let b = run(123);
    assert_eq!(a, b, "same seed must reproduce bit-identically");
    let c = run(124);
    assert_ne!(a, c, "different seed should differ");
}

#[test]
fn ideal_and_high_snr_ota_agree_closely() {
    if !artifacts_present() {
        return;
    }
    let run = |agg: Aggregation, snr: f32, perfect: bool| {
        let mut cfg = tiny_cfg();
        cfg.rounds = 1;
        cfg.aggregation = agg;
        cfg.channel.snr_db = snr;
        cfg.channel.perfect_csi = perfect;
        let mut coord = Coordinator::new(cfg).unwrap();
        coord.run().unwrap().final_loss
    };
    let ideal = run(Aggregation::Ideal, 20.0, false);
    let ota_clean = run(Aggregation::OtaAnalog, 120.0, true);
    assert!(
        (ideal - ota_clean).abs() < 1e-3,
        "ideal {ideal} vs clean-channel OTA {ota_clean}"
    );
}

#[test]
fn low_snr_degrades_aggregation() {
    if !artifacts_present() {
        return;
    }
    let mse_at = |snr: f32| {
        let mut cfg = tiny_cfg();
        cfg.rounds = 1;
        cfg.channel.snr_db = snr;
        cfg.channel.perfect_csi = true;
        let mut coord = Coordinator::new(cfg).unwrap();
        let report = coord.run().unwrap();
        report.log.rounds[0].ota_mse
    };
    let low = mse_at(5.0);
    let high = mse_at(30.0);
    assert!(
        low > high * 10.0,
        "OTA MSE should fall sharply with SNR: 5dB {low} vs 30dB {high}"
    );
}

#[test]
fn homogeneous_4bit_requant_matches_global() {
    if !artifacts_present() {
        return;
    }
    let mut cfg = tiny_cfg();
    cfg.rounds = 1;
    cfg.scheme = Scheme::parse("4,4,4").unwrap();
    let mut coord = Coordinator::new(cfg).unwrap();
    let report = coord.run().unwrap();
    assert_eq!(report.requant.len(), 1);
    assert_eq!(report.requant[0].precision.bits(), 4);
}

#[test]
fn config_validation_rejects_undivisible_scheme() {
    let mut cfg = tiny_cfg();
    cfg.clients = 14; // not divisible by 3 groups
    cfg.clients_per_round = 14;
    assert!(cfg.validate().is_err());
}

#[test]
fn experiment_builder_defaults_match_coordinator() {
    if !artifacts_present() {
        return;
    }
    let mut cfg = tiny_cfg();
    cfg.rounds = 1;
    let report_coord = Coordinator::new(cfg.clone()).unwrap().run().unwrap();
    let report_exp = Experiment::builder(cfg).build().unwrap().run().unwrap();
    // the builder with default parts IS the coordinator: bit-identical
    assert_eq!(
        report_coord.final_accuracy.to_bits(),
        report_exp.final_accuracy.to_bits()
    );
    assert_eq!(
        report_coord.final_loss.to_bits(),
        report_exp.final_loss.to_bits()
    );
    assert_eq!(
        report_coord.log.rounds[0].ota_mse.to_bits(),
        report_exp.log.rounds[0].ota_mse.to_bits()
    );
    assert_eq!(report_coord.label, report_exp.label);
}

#[test]
fn snr_adaptive_policy_and_awgn_model_run_end_to_end() {
    if !artifacts_present() {
        return;
    }
    let mut cfg = tiny_cfg();
    cfg.rounds = 1;
    cfg.policy = PolicyKind::SnrAdaptive;
    cfg.channel.model = mpota::channel::FadingKind::Awgn;
    let mut exp = Experiment::builder(cfg).build().unwrap();
    let report = exp.run().unwrap();
    assert_eq!(report.log.rounds.len(), 1);
    // AWGN model: nobody is ever silenced
    assert_eq!(report.log.rounds[0].participants, 15);
    assert!(report.final_loss.is_finite());
    assert!(report.label.starts_with("snr-adaptive@"));
}
