//! Property suite for the bit-packed payload plane (PR-9).
//!
//! Three phases, one `#[test]` (the counting allocator is process-global,
//! so parallel tests would pollute the phase-3 measurement — same policy
//! as `tests/alloc_counter.rs`):
//!
//! 1. **Round-trip**: for every supported precision, `CASES` random
//!    vectors (including the generator's degenerate all-zero/constant
//!    cases) satisfy `unpack(pack(x)) == fake_quant(x)` bit for bit —
//!    packing is exactly the transmission quantization, floor rounding.
//! 2. **Mixed-width superposition**: a plane holding one row per
//!    supported width superposes through `fused::superpose_packed`
//!    bit-identically to `fused::superpose` over the fake-quantized f32
//!    rows the packed codes decode to, at threads 1 and 4.
//! 3. **Zero-alloc streaming**: a warm Session streaming bit-packed
//!    shards (pack → accumulate → finalize, every row kind) performs
//!    ZERO heap allocations per round.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: every method delegates to `System` verbatim — the only addition
// is a relaxed atomic count — so System's GlobalAlloc contract carries over.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: forwarded to `System` unchanged.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: forwarded to `System` unchanged (plus the count).
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use mpota::channel::{ChannelConfig, C32};
use mpota::kernels::{fused, PackedPlane, PayloadPlane};
use mpota::quant::{self, Precision, SUPPORTED_LEVELS};
use mpota::rng::Rng;
use mpota::sim::{AnalogOta, RayleighPilot, Session};
use mpota::testing;

#[test]
fn packed_plane_properties() {
    // ---- phase 1: pack/unpack round-trip per width ----
    for &bits in SUPPORTED_LEVELS.iter() {
        let p = Precision::of(bits);
        testing::check_vec(
            &format!("packed-roundtrip-{bits}"),
            testing::CASES,
            2048,
            |v| {
                let mut plane = PackedPlane::new();
                plane.reset(std::slice::from_ref(&p), v.len());
                plane.pack_row(0, v);
                let mut dst = vec![0.0f32; v.len()];
                plane.unpack_row_into(0, &mut dst);
                let want = quant::fake_quant(v, p);
                dst.iter()
                    .zip(want.iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits())
            },
        );
    }

    // ---- phase 2: mixed-width superposition vs the f32 reference ----
    let levels: Vec<Precision> =
        SUPPORTED_LEVELS.iter().map(|&b| Precision::of(b)).collect();
    let k = levels.len();
    // mpota-lint: allow(R4): property fixture root for this test binary
    let root = Rng::seed_from(0x9ACC_ED01);
    for case in 0..16u64 {
        let mut rng = root.substream(case);
        let n = 1 + rng.below(10_000);
        let mut packed = PackedPlane::new();
        packed.reset(&levels, n);
        let mut fq = PayloadPlane::zeros(k, n);
        let mut raw = vec![0.0f32; n];
        for (r, &p) in levels.iter().enumerate() {
            let scale = 10f32.powf(rng.uniform_in(-2.0, 2.0));
            rng.fill_normal(&mut raw, 0.0, scale);
            packed.pack_row(r, &raw);
            let q = quant::fake_quant(&raw, p);
            fq.row_mut(r).copy_from_slice(&q);
        }
        // random active subset with non-trivial complex gains
        let active: Vec<(usize, C32)> = (0..k)
            .filter(|_| rng.below(4) != 0)
            .map(|i| (i, C32::new(rng.normal_f32(1.0, 0.3), rng.normal_f32(0.0, 0.3))))
            .collect();
        let mut want_re = vec![0.0f32; n];
        let mut want_im = vec![0.0f32; n];
        let mut want_id = vec![0.0f32; n];
        fused::superpose(&fq, &active, &mut want_re, &mut want_im, &mut want_id, 1);
        for threads in [1usize, 4] {
            let mut y_re = vec![0.0f32; n];
            let mut y_im = vec![0.0f32; n];
            let mut ideal = vec![0.0f32; n];
            fused::superpose_packed(
                &packed, &active, &mut y_re, &mut y_im, &mut ideal, threads,
            );
            for (name, got, want) in [
                ("y_re", &y_re, &want_re),
                ("y_im", &y_im, &want_im),
                ("ideal", &ideal, &want_id),
            ] {
                let diverged = got
                    .iter()
                    .zip(want.iter())
                    .position(|(a, b)| a.to_bits() != b.to_bits());
                assert_eq!(
                    diverged, None,
                    "case {case}: {name} diverged (n={n} threads={threads})"
                );
            }
        }
    }

    // ---- phase 3: packed streaming stays zero-alloc once warm ----
    // the streaming-round shape over every row kind: raw rows are packed
    // into the recycled PackedPlane and superposed through the session's
    // persistent air accumulator; after two warmup rounds grow the
    // buffers, six more rounds must not allocate at all
    let n = 4_096usize;
    // mpota-lint: allow(R4): fixed seed for the zero-alloc fixture
    let root = Rng::seed_from(77);
    let mut session = Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        Box::new(AnalogOta),
        root.stream("channel"),
        root.stream("noise"),
        4,
    );
    assert!(session.supports_packed());
    let precisions: Vec<Precision> =
        [32u8, 24, 16, 12, 8, 6, 4, 3, 2].iter().map(|&b| Precision::of(b)).collect();
    let kk = precisions.len();
    let shard = 4usize;
    let mut src = PayloadPlane::new();
    let mut packed = PackedPlane::new();
    let mut fill_rng = root.stream("payloads");
    let mut round = |t: usize,
                     session: &mut Session,
                     src: &mut PayloadPlane,
                     packed: &mut PackedPlane,
                     fill_rng: &mut Rng| {
        session.begin_aggregate(t, kk, n);
        let mut lo = 0usize;
        while lo < kk {
            let hi = (lo + shard).min(kk);
            src.reset(hi - lo, n);
            for r in 0..hi - lo {
                fill_rng.fill_normal(src.row_mut(r), 0.0, 1.0);
            }
            packed.reset(&precisions[lo..hi], n);
            for r in 0..hi - lo {
                packed.pack_row(r, src.row(r));
            }
            session.accumulate_packed_shard_masked(
                packed,
                lo,
                &precisions[lo..hi],
                None,
            );
            lo = hi;
        }
        let stats = session.finalize_aggregate(t, &precisions);
        std::hint::black_box(stats.participants);
    };
    for t in 1..=2 {
        round(t, &mut session, &mut src, &mut packed, &mut fill_rng);
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for t in 3..=8 {
        round(t, &mut session, &mut src, &mut packed, &mut fill_rng);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "warm packed streaming allocated {} times",
        after - before
    );
}
