//! Bit-exact parity: rust Algorithm-2 vs the jnp oracle's golden vectors.
//!
//! `artifacts/goldens.json` is emitted by `python/compile/aot.py` from
//! `kernels/ref.py`.  Requires `make artifacts` to have run; the test is
//! skipped (with a loud message) if the artifacts are missing so that
//! `cargo test` works in a fresh checkout.

use mpota::json;
use mpota::quant::{self, Precision};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(
        std::env::var("MPOTA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )
}

#[test]
fn quantization_matches_jnp_bit_for_bit() {
    let path = artifacts_dir().join("goldens.json");
    if !path.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)", path.display());
        return;
    }
    let doc = json::parse_file(&path).unwrap();
    let cases = doc.req("cases").unwrap().as_array().unwrap();
    assert!(cases.len() >= 40, "suspiciously few golden cases");
    for case in cases {
        let name = case.req("name").unwrap().as_str().unwrap();
        let bits = case.req("bits").unwrap().as_usize().unwrap() as u8;
        let rounding = match case.get("rounding").map(|v| v.as_str()) {
            Some(Ok("nearest")) => quant::Rounding::Nearest,
            _ => quant::Rounding::Floor,
        };
        let input = case.req("input").unwrap().as_f32_vec().unwrap();
        let expect = case.req("expect").unwrap().as_f32_vec().unwrap();
        let got =
            quant::fake_quant_mode(&input, Precision::new(bits).unwrap(), rounding);
        assert_eq!(got.len(), expect.len(), "{name}");
        for (i, (g, e)) in got.iter().zip(expect.iter()).enumerate() {
            assert_eq!(
                g.to_bits(),
                e.to_bits(),
                "golden '{name}' diverges at [{i}]: rust {g} vs jnp {e}"
            );
        }
    }
}
