//! SHARD-INVARIANCE pins for the streaming round pipeline.
//!
//! The coordinator streams each round's K selected clients through
//! `RunConfig::shard_size`-row payload shards: every shard trains on the
//! exec pool, fills a small reusable plane, and is fused-superposed into
//! the session's persistent air accumulator before the next shard reuses
//! the buffers (round memory O(shard·N + K) instead of O(K·N)).
//!
//! The repo's hard contract is that this is a pure memory/scheduling
//! transformation: for a fixed seed, FULL-RUN trajectories — global model
//! bits, per-round train loss, OTA MSE, server loss, participants, final
//! report — are bit-identical across every `shard_size` × `threads` ×
//! `workers` combination, under every channel model.  These tests mirror
//! the PR-4 determinism pins in `tests/sim.rs` (same deterministic mock
//! `TrainBackend`, now shared via `mpota::testing`), adding the shard
//! axis.

use std::rc::Rc;

use mpota::channel::FadingKind;
use mpota::config::{RunConfig, SelectionKind};
use mpota::coordinator::RunReport;
use mpota::fl::Scheme;
use mpota::runtime::Runtime;
use mpota::sim::Experiment;
use mpota::testing::{mock_artifacts_dir, MockTrainer};

fn base_cfg(model: FadingKind, dir: &std::path::Path) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = dir.to_path_buf();
    cfg.variant = "mock".into();
    cfg.clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = 3;
    cfg.train_samples = 96;
    cfg.test_samples = 32;
    cfg.scheme = Scheme::parse("16,8,4").unwrap();
    cfg.channel.model = model;
    if model == FadingKind::GaussMarkov {
        cfg.channel.rho = 0.85;
    }
    cfg
}

/// Run the full experiment and flatten everything comparable to bits.
fn run(cfg: RunConfig, rt: Rc<Runtime>) -> (Vec<u32>, RunReport) {
    let mut exp = Experiment::builder(cfg)
        .runtime(rt)
        .backend(MockTrainer)
        .build()
        .unwrap();
    let report = exp.run().unwrap();
    let bits: Vec<u32> = exp.global_model().iter().map(|v| v.to_bits()).collect();
    (bits, report)
}

fn assert_trajectories_equal(
    label: &str,
    (theta_ref, rep_ref): &(Vec<u32>, RunReport),
    (theta, rep): &(Vec<u32>, RunReport),
) {
    assert_eq!(theta_ref, theta, "{label}: global model diverged");
    assert_eq!(rep_ref.log.rounds.len(), rep.log.rounds.len(), "{label}");
    for (a, b) in rep_ref.log.rounds.iter().zip(rep.log.rounds.iter()) {
        assert_eq!(a.participants, b.participants, "{label} round {}", a.round);
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "{label} round {}",
            a.round
        );
        assert_eq!(
            a.train_accuracy.to_bits(),
            b.train_accuracy.to_bits(),
            "{label} round {}",
            a.round
        );
        assert_eq!(
            a.ota_mse.to_bits(),
            b.ota_mse.to_bits(),
            "{label} round {}",
            a.round
        );
        assert_eq!(
            a.server_loss.to_bits(),
            b.server_loss.to_bits(),
            "{label} round {}",
            a.round
        );
        assert_eq!(
            a.energy_joules.to_bits(),
            b.energy_joules.to_bits(),
            "{label} round {}",
            a.round
        );
    }
    assert_eq!(
        rep_ref.final_accuracy.to_bits(),
        rep.final_accuracy.to_bits(),
        "{label}: final accuracy"
    );
    assert_eq!(
        rep_ref.final_loss.to_bits(),
        rep.final_loss.to_bits(),
        "{label}: final loss"
    );
}

#[test]
fn full_runs_bit_identical_across_shard_sizes_threads_and_workers() {
    // the acceptance pin: shard_size ∈ {1, 3, K} × {threads, workers} ∈
    // {1, 4}, under rayleigh, gauss_markov and path_loss, all reproduce
    // the unsharded sequential trajectory bit for bit
    let dir = mock_artifacts_dir("shardinv_full");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    for model in
        [FadingKind::Rayleigh, FadingKind::GaussMarkov, FadingKind::PathLoss]
    {
        // reference: unsharded (shard_size 0 = one whole-round shard),
        // sequential
        let reference = run(base_cfg(model, &dir), rt.clone());
        assert_eq!(reference.1.log.rounds.len(), 3);
        for shard in [1usize, 3, 6] {
            for (threads, workers) in [(1usize, 1usize), (4, 1), (1, 4), (4, 4)] {
                let mut cfg = base_cfg(model, &dir);
                cfg.shard_size = shard;
                cfg.threads = threads;
                cfg.workers = workers;
                let got = run(cfg, rt.clone());
                assert_trajectories_equal(
                    &format!(
                        "{model:?} shard={shard} threads={threads} workers={workers}"
                    ),
                    &reference,
                    &got,
                );
            }
        }
    }
}

#[test]
fn pipelined_rounds_bit_identical_across_depths() {
    // the PR-6 acceptance pin: pipeline_depth ∈ {0, 1, 2} × shard_size ∈
    // {1, 3, K} × {threads, workers} ∈ {1, 4} all reproduce the serial
    // trajectory bit for bit — pipelining only changes WHEN superposition
    // happens relative to training, never the draws or the accumulation
    // order
    let dir = mock_artifacts_dir("shardinv_pipe");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let reference = run(base_cfg(FadingKind::Rayleigh, &dir), rt.clone());
    for depth in [0usize, 1, 2] {
        for shard in [1usize, 3, 6] {
            for (threads, workers) in [(1usize, 1usize), (4, 4)] {
                let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
                cfg.pipeline_depth = depth;
                cfg.shard_size = shard;
                cfg.threads = threads;
                cfg.workers = workers;
                let got = run(cfg, rt.clone());
                assert_trajectories_equal(
                    &format!(
                        "depth={depth} shard={shard} threads={threads} \
                         workers={workers}"
                    ),
                    &reference,
                    &got,
                );
            }
        }
    }
}

#[test]
fn straggler_runs_invariant_across_pipeline_shard_and_workers() {
    // exclusion is decided up front per round from its own RNG stream, so
    // a lossy run is ALSO bit-identical across every scheduling axis
    let dir = mock_artifacts_dir("shardinv_straggler");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |depth: usize, shard: usize, workers: usize| {
        let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
        cfg.rounds = 4;
        cfg.deadline_s = 0.055;
        cfg.dropout_p = 0.2;
        cfg.pipeline_depth = depth;
        cfg.shard_size = shard;
        cfg.workers = workers;
        cfg
    };
    let reference = run(mk(0, 0, 1), rt.clone());
    // the policy must actually bite in this fixture or the pin is vacuous
    assert!(
        reference.1.log.rounds.iter().any(|r| r.participants < 6),
        "straggler fixture excluded nobody"
    );
    for depth in [0usize, 2] {
        for shard in [1usize, 3] {
            for workers in [1usize, 4] {
                let got = run(mk(depth, shard, workers), rt.clone());
                assert_trajectories_equal(
                    &format!("straggler depth={depth} shard={shard} workers={workers}"),
                    &reference,
                    &got,
                );
            }
        }
    }
}

#[test]
fn disabled_deadline_is_byte_identical_to_the_deadline_free_engine() {
    // deadline_s = 0 and dropout_p = 0 never derive a policy, never
    // consume the "straggler" stream: changing the OTHER straggler knobs
    // must leave the trajectory untouched, byte for byte (the PR-5
    // baseline pin)
    let dir = mock_artifacts_dir("shardinv_disabled");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let reference = run(base_cfg(FadingKind::Rayleigh, &dir), rt.clone());
    let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
    cfg.compute_s = 9.0; // would exclude everyone — if a deadline existed
    cfg.latency_jitter = 2.0;
    cfg.slot_s = 0.5;
    cfg.dropout_burst = 50.0;
    let got = run(cfg, rt.clone());
    assert_trajectories_equal("disabled straggler knobs", &reference, &got);
}

#[test]
fn sampled_selection_runs_are_shard_invariant_too() {
    // K < N with the Floyd's-sampling selector: the shard axis still
    // never changes the trajectory (selection happens before sharding,
    // and client results are per-client deterministic)
    let dir = mock_artifacts_dir("shardinv_sampled");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |shard: usize, threads: usize, workers: usize| {
        let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
        cfg.clients = 12;
        cfg.clients_per_round = 6;
        cfg.selection = SelectionKind::Sampled;
        cfg.shard_size = shard;
        cfg.threads = threads;
        cfg.workers = workers;
        cfg
    };
    let reference = run(mk(0, 1, 1), rt.clone());
    for r in &reference.1.log.rounds {
        assert!(r.participants <= 6, "at most K participants");
    }
    for shard in [1usize, 2, 6] {
        for (threads, workers) in [(1usize, 1usize), (4, 4)] {
            let got = run(mk(shard, threads, workers), rt.clone());
            assert_trajectories_equal(
                &format!("sampled shard={shard} threads={threads} workers={workers}"),
                &reference,
                &got,
            );
        }
    }
}

#[test]
fn id_keyed_channel_state_runs_are_invariant_across_every_axis() {
    // The client-identity pin, cross-axis: under SampledK (K < N) the
    // STATEFUL channel models key their per-client memory (AR(1) fades,
    // geometry sites) by client id in a bounded LRU, and the lazy
    // ClientFleet materializes clients on first selection.  None of that
    // may depend on HOW the round is scheduled: pipeline_depth ×
    // shard_size × threads × workers all reproduce the serial unsharded
    // trajectory bit for bit, per channel model.  (Slot-keyed state
    // passed this family only under full participation, where slot == id
    // hides the aliasing; K < N with persistent state is exactly the
    // regime the id-keying fix exists for.)
    let dir = mock_artifacts_dir("shardinv_idkeyed");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |model: FadingKind, depth: usize, shard: usize, threads: usize, workers: usize| {
        let mut cfg = base_cfg(model, &dir);
        cfg.clients = 12;
        cfg.clients_per_round = 4;
        cfg.rounds = 5; // enough rounds that ids re-enter in new slots
        cfg.selection = SelectionKind::Sampled;
        cfg.pipeline_depth = depth;
        cfg.shard_size = shard;
        cfg.threads = threads;
        cfg.workers = workers;
        cfg
    };
    for model in [FadingKind::GaussMarkov, FadingKind::PathLoss] {
        let reference = run(mk(model, 0, 0, 1, 1), rt.clone());
        assert_eq!(reference.1.log.rounds.len(), 5);
        for depth in [0usize, 2] {
            for shard in [1usize, 3] {
                for (threads, workers) in [(1usize, 4usize), (4, 4)] {
                    let got = run(mk(model, depth, shard, threads, workers), rt.clone());
                    assert_trajectories_equal(
                        &format!(
                            "{model:?} depth={depth} shard={shard} \
                             threads={threads} workers={workers}"
                        ),
                        &reference,
                        &got,
                    );
                }
            }
        }
    }
}

#[test]
fn profiling_policy_feedback_is_schedule_invariant() {
    // The ProfilingPlanner folds per-round feedback (per-client channel
    // gains, energy spend, local losses) into its history — all of it
    // assembled AFTER the round's client phase from id-keyed state.  The
    // planner's precision decisions (and hence the whole trajectory) must
    // be identical across scheduling axes, or feedback would be reading
    // schedule-dependent state.
    let dir = mock_artifacts_dir("shardinv_profiling");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |depth: usize, shard: usize, workers: usize| {
        let mut cfg = base_cfg(FadingKind::GaussMarkov, &dir);
        cfg.clients = 12;
        cfg.clients_per_round = 4;
        cfg.rounds = 5;
        cfg.selection = SelectionKind::Sampled;
        cfg.policy = mpota::config::PolicyKind::Profiling;
        cfg.pipeline_depth = depth;
        cfg.shard_size = shard;
        cfg.workers = workers;
        cfg
    };
    let reference = run(mk(0, 0, 1), rt.clone());
    for depth in [0usize, 2] {
        for shard in [1usize, 3] {
            for workers in [1usize, 4] {
                let got = run(mk(depth, shard, workers), rt.clone());
                assert_trajectories_equal(
                    &format!("profiling depth={depth} shard={shard} workers={workers}"),
                    &reference,
                    &got,
                );
            }
        }
    }
}

#[test]
fn packed_transport_is_bit_identical_to_f32_staging_across_every_axis() {
    // The PR-9 acceptance pin: bit-packed transport planes are a pure
    // memory-layout change.  `PackedPlane::pack_row` stores each row at
    // its assigned width and the fused unpack-superpose kernels decode
    // exactly `fake_quant(x)` bit for bit, so packed-on trajectories
    // reproduce packed-off trajectories across pipeline_depth ×
    // shard_size × threads × workers, per aggregation architecture.
    let dir = mock_artifacts_dir("shardinv_packed");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    for agg in [
        mpota::config::Aggregation::OtaAnalog,
        mpota::config::Aggregation::Digital,
        mpota::config::Aggregation::Ideal,
    ] {
        let mut ref_cfg = base_cfg(FadingKind::Rayleigh, &dir);
        ref_cfg.aggregation = agg;
        ref_cfg.packed_planes = false;
        let reference = run(ref_cfg, rt.clone());
        for depth in [0usize, 2] {
            for shard in [0usize, 1, 3] {
                for (threads, workers) in [(1usize, 1usize), (4, 4)] {
                    let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
                    cfg.aggregation = agg;
                    cfg.packed_planes = true;
                    cfg.pipeline_depth = depth;
                    cfg.shard_size = shard;
                    cfg.threads = threads;
                    cfg.workers = workers;
                    let got = run(cfg, rt.clone());
                    assert_trajectories_equal(
                        &format!(
                            "{agg:?} packed depth={depth} shard={shard} \
                             threads={threads} workers={workers}"
                        ),
                        &reference,
                        &got,
                    );
                }
            }
        }
    }
}

#[test]
fn packed_transport_covers_every_row_kind() {
    // One scheme that exercises every PackedPlane row representation:
    // 32-bit rows ride as raw f32 words, 24-bit as mantissa-masked words,
    // 12-bit as top-16 truncations (two per word), 2-bit as LSB-first
    // affine code lanes — all still bit-identical to f32 staging, sharded
    // and pipelined.
    let dir = mock_artifacts_dir("shardinv_packed_kinds");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |packed: bool, depth: usize, shard: usize| {
        let mut cfg = base_cfg(FadingKind::GaussMarkov, &dir);
        cfg.scheme = Scheme::parse("32,24,12,2").unwrap();
        cfg.packed_planes = packed;
        cfg.pipeline_depth = depth;
        cfg.shard_size = shard;
        cfg.threads = 4;
        cfg.workers = 4;
        cfg
    };
    let reference = run(mk(false, 0, 0), rt.clone());
    for depth in [0usize, 2] {
        for shard in [0usize, 2] {
            let got = run(mk(true, depth, shard), rt.clone());
            assert_trajectories_equal(
                &format!("row kinds depth={depth} shard={shard}"),
                &reference,
                &got,
            );
        }
    }
}

#[test]
fn dirichlet_gradstats_runs_bit_identical_across_every_axis() {
    // Non-IID partitions ride the same CSR shard path as IID through the
    // lazy fleet, and the GradStatsBackend's step is a pure function of
    // its call inputs — so a Dirichlet full-FL run (the convergence-suite
    // configuration) is ALSO bit-identical across pipeline_depth ×
    // shard_size × threads × workers.
    let dir = mock_artifacts_dir("shardinv_dirichlet");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |depth: usize, shard: usize, threads: usize, workers: usize| {
        let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
        cfg.partition = mpota::config::PartitionKind::Dirichlet;
        cfg.alpha = 0.3;
        cfg.skew_zipf = 0.8;
        cfg.train_samples = 192; // room for unequal shards above train_batch
        cfg.pipeline_depth = depth;
        cfg.shard_size = shard;
        cfg.threads = threads;
        cfg.workers = workers;
        cfg
    };
    let run_gs = |cfg: RunConfig| {
        let mut exp = Experiment::builder(cfg)
            .runtime(rt.clone())
            .backend_boxed(Box::new(mpota::testing::GradStatsBackend::for_mock()))
            .build()
            .unwrap();
        let report = exp.run().unwrap();
        let bits: Vec<u32> = exp.global_model().iter().map(|v| v.to_bits()).collect();
        (bits, report)
    };
    let reference = run_gs(mk(0, 0, 1, 1));
    assert_eq!(reference.1.log.rounds.len(), 3);
    for depth in [0usize, 2] {
        for shard in [1usize, 3] {
            for (threads, workers) in [(1usize, 4usize), (4, 1), (4, 4)] {
                let got = run_gs(mk(depth, shard, threads, workers));
                assert_trajectories_equal(
                    &format!(
                        "dirichlet depth={depth} shard={shard} threads={threads} \
                         workers={workers}"
                    ),
                    &reference,
                    &got,
                );
            }
        }
    }
}

#[test]
fn shard_size_larger_than_k_is_one_shard() {
    // shard_size > K clamps to one whole-round shard — same trajectory
    let dir = mock_artifacts_dir("shardinv_clamp");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let reference = run(base_cfg(FadingKind::Rayleigh, &dir), rt.clone());
    let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
    cfg.shard_size = 1000;
    let got = run(cfg, rt.clone());
    assert_trajectories_equal("shard_size > K", &reference, &got);
}

#[test]
fn sharded_rounds_under_every_aggregation_path() {
    // digital and ideal aggregators stream too: sharded == unsharded per
    // seed for each aggregation architecture
    let dir = mock_artifacts_dir("shardinv_agg");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    for agg in [
        mpota::config::Aggregation::OtaAnalog,
        mpota::config::Aggregation::Digital,
        mpota::config::Aggregation::Ideal,
    ] {
        let mut ref_cfg = base_cfg(FadingKind::Rayleigh, &dir);
        ref_cfg.aggregation = agg;
        let reference = run(ref_cfg, rt.clone());
        for shard in [1usize, 3] {
            let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
            cfg.aggregation = agg;
            cfg.shard_size = shard;
            cfg.threads = 4;
            cfg.workers = 4;
            let got = run(cfg, rt.clone());
            assert_trajectories_equal(&format!("{agg:?} shard={shard}"), &reference, &got);
        }
    }
}
