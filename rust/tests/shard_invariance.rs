//! SHARD-INVARIANCE pins for the streaming round pipeline.
//!
//! The coordinator streams each round's K selected clients through
//! `RunConfig::shard_size`-row payload shards: every shard trains on the
//! exec pool, fills a small reusable plane, and is fused-superposed into
//! the session's persistent air accumulator before the next shard reuses
//! the buffers (round memory O(shard·N + K) instead of O(K·N)).
//!
//! The repo's hard contract is that this is a pure memory/scheduling
//! transformation: for a fixed seed, FULL-RUN trajectories — global model
//! bits, per-round train loss, OTA MSE, server loss, participants, final
//! report — are bit-identical across every `shard_size` × `threads` ×
//! `workers` combination, under every channel model.  These tests mirror
//! the PR-4 determinism pins in `tests/sim.rs` (same deterministic mock
//! `TrainBackend`, now shared via `mpota::testing`), adding the shard
//! axis.

use std::rc::Rc;

use mpota::channel::FadingKind;
use mpota::config::{RunConfig, SelectionKind};
use mpota::coordinator::RunReport;
use mpota::fl::Scheme;
use mpota::runtime::Runtime;
use mpota::sim::Experiment;
use mpota::testing::{mock_artifacts_dir, MockTrainer};

fn base_cfg(model: FadingKind, dir: &std::path::Path) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = dir.to_path_buf();
    cfg.variant = "mock".into();
    cfg.clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = 3;
    cfg.train_samples = 96;
    cfg.test_samples = 32;
    cfg.scheme = Scheme::parse("16,8,4").unwrap();
    cfg.channel.model = model;
    if model == FadingKind::GaussMarkov {
        cfg.channel.rho = 0.85;
    }
    cfg
}

/// Run the full experiment and flatten everything comparable to bits.
fn run(cfg: RunConfig, rt: Rc<Runtime>) -> (Vec<u32>, RunReport) {
    let mut exp = Experiment::builder(cfg)
        .runtime(rt)
        .backend(MockTrainer)
        .build()
        .unwrap();
    let report = exp.run().unwrap();
    let bits: Vec<u32> = exp.global_model().iter().map(|v| v.to_bits()).collect();
    (bits, report)
}

fn assert_trajectories_equal(
    label: &str,
    (theta_ref, rep_ref): &(Vec<u32>, RunReport),
    (theta, rep): &(Vec<u32>, RunReport),
) {
    assert_eq!(theta_ref, theta, "{label}: global model diverged");
    assert_eq!(rep_ref.log.rounds.len(), rep.log.rounds.len(), "{label}");
    for (a, b) in rep_ref.log.rounds.iter().zip(rep.log.rounds.iter()) {
        assert_eq!(a.participants, b.participants, "{label} round {}", a.round);
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "{label} round {}",
            a.round
        );
        assert_eq!(
            a.train_accuracy.to_bits(),
            b.train_accuracy.to_bits(),
            "{label} round {}",
            a.round
        );
        assert_eq!(
            a.ota_mse.to_bits(),
            b.ota_mse.to_bits(),
            "{label} round {}",
            a.round
        );
        assert_eq!(
            a.server_loss.to_bits(),
            b.server_loss.to_bits(),
            "{label} round {}",
            a.round
        );
        assert_eq!(
            a.energy_joules.to_bits(),
            b.energy_joules.to_bits(),
            "{label} round {}",
            a.round
        );
    }
    assert_eq!(
        rep_ref.final_accuracy.to_bits(),
        rep.final_accuracy.to_bits(),
        "{label}: final accuracy"
    );
    assert_eq!(
        rep_ref.final_loss.to_bits(),
        rep.final_loss.to_bits(),
        "{label}: final loss"
    );
}

#[test]
fn full_runs_bit_identical_across_shard_sizes_threads_and_workers() {
    // the acceptance pin: shard_size ∈ {1, 3, K} × {threads, workers} ∈
    // {1, 4}, under rayleigh, gauss_markov and path_loss, all reproduce
    // the unsharded sequential trajectory bit for bit
    let dir = mock_artifacts_dir("shardinv_full");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    for model in
        [FadingKind::Rayleigh, FadingKind::GaussMarkov, FadingKind::PathLoss]
    {
        // reference: unsharded (shard_size 0 = one whole-round shard),
        // sequential
        let reference = run(base_cfg(model, &dir), rt.clone());
        assert_eq!(reference.1.log.rounds.len(), 3);
        for shard in [1usize, 3, 6] {
            for (threads, workers) in [(1usize, 1usize), (4, 1), (1, 4), (4, 4)] {
                let mut cfg = base_cfg(model, &dir);
                cfg.shard_size = shard;
                cfg.threads = threads;
                cfg.workers = workers;
                let got = run(cfg, rt.clone());
                assert_trajectories_equal(
                    &format!(
                        "{model:?} shard={shard} threads={threads} workers={workers}"
                    ),
                    &reference,
                    &got,
                );
            }
        }
    }
}

#[test]
fn sampled_selection_runs_are_shard_invariant_too() {
    // K < N with the Floyd's-sampling selector: the shard axis still
    // never changes the trajectory (selection happens before sharding,
    // and client results are per-client deterministic)
    let dir = mock_artifacts_dir("shardinv_sampled");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |shard: usize, threads: usize, workers: usize| {
        let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
        cfg.clients = 12;
        cfg.clients_per_round = 6;
        cfg.selection = SelectionKind::Sampled;
        cfg.shard_size = shard;
        cfg.threads = threads;
        cfg.workers = workers;
        cfg
    };
    let reference = run(mk(0, 1, 1), rt.clone());
    for r in &reference.1.log.rounds {
        assert!(r.participants <= 6, "at most K participants");
    }
    for shard in [1usize, 2, 6] {
        for (threads, workers) in [(1usize, 1usize), (4, 4)] {
            let got = run(mk(shard, threads, workers), rt.clone());
            assert_trajectories_equal(
                &format!("sampled shard={shard} threads={threads} workers={workers}"),
                &reference,
                &got,
            );
        }
    }
}

#[test]
fn shard_size_larger_than_k_is_one_shard() {
    // shard_size > K clamps to one whole-round shard — same trajectory
    let dir = mock_artifacts_dir("shardinv_clamp");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let reference = run(base_cfg(FadingKind::Rayleigh, &dir), rt.clone());
    let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
    cfg.shard_size = 1000;
    let got = run(cfg, rt.clone());
    assert_trajectories_equal("shard_size > K", &reference, &got);
}

#[test]
fn sharded_rounds_under_every_aggregation_path() {
    // digital and ideal aggregators stream too: sharded == unsharded per
    // seed for each aggregation architecture
    let dir = mock_artifacts_dir("shardinv_agg");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    for agg in [
        mpota::config::Aggregation::OtaAnalog,
        mpota::config::Aggregation::Digital,
        mpota::config::Aggregation::Ideal,
    ] {
        let mut ref_cfg = base_cfg(FadingKind::Rayleigh, &dir);
        ref_cfg.aggregation = agg;
        let reference = run(ref_cfg, rt.clone());
        for shard in [1usize, 3] {
            let mut cfg = base_cfg(FadingKind::Rayleigh, &dir);
            cfg.aggregation = agg;
            cfg.shard_size = shard;
            cfg.threads = 4;
            cfg.workers = 4;
            let got = run(cfg, rt.clone());
            assert_trajectories_equal(&format!("{agg:?} shard={shard}"), &reference, &got);
        }
    }
}
