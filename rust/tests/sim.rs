//! The composable simulation API's contracts:
//!
//! 1. GOLDENS — the default trait impls ([`AnalogOta`], [`DigitalOrthogonal`],
//!    [`IdealFedAvg`] behind a [`Session`], [`StaticScheme`] policy) are
//!    bit-identical per seed to the pre-redesign enum-dispatch paths
//!    (direct `aggregate_plane_into` calls over a hand-drawn channel) at
//!    threads=1 AND threads=N.
//! 2. SEAMS — a mock [`ChannelModel`] and a counting [`RoundObserver`]
//!    plug in and are actually driven; a custom [`Aggregator`] works end
//!    to end through the session.
//! 3. RNG discipline — aggregators that need no channel skip the draw and
//!    its RNG consumption, exactly like the old enum dispatch.
//! 4. EXECUTION RUNTIME — full runs through the coordinator (against a
//!    deterministic mock `TrainBackend`, so no PJRT artifacts are needed)
//!    are bit-identical per seed for every `{threads, workers} ∈ {1, 4}`
//!    combination, under each channel model; the PJRT gateway path fails
//!    cleanly (no hang) when the runtime cannot execute.

use std::rc::Rc;

use mpota::channel::{
    pilot, ChannelConfig, ClientChannel, FadingKind, Precode, RoundChannel, C32,
};
use mpota::config::RunConfig;
use mpota::fl::{self, Scheme};
use mpota::kernels::PayloadPlane;
use mpota::metrics::RoundRecord;
use mpota::ota::{self, AggregateStats};
use mpota::quant::{fake_quant, Precision};
use mpota::rng::Rng;
use mpota::runtime::Runtime;
use mpota::sim::{
    AggCtx, AggScratch, Aggregator, AnalogOta, ChannelModel, DigitalOrthogonal,
    EnergyBudget, Experiment, GaussMarkov, IdealFedAvg, LossPlateau, PathLossGeometry,
    PolicyCtx, PrecisionPolicy, RayleighPilot, RoundObserver, Session, StaticScheme,
};

const K: usize = 15;
const N: usize = 20_000; // large even N: crosses the parallel thresholds

fn mixed_precisions() -> Vec<Precision> {
    let scheme = Scheme::parse("16,8,4").unwrap();
    scheme.client_precisions(K).unwrap()
}

/// K quantized client payloads, shaped like real round traffic.
fn quantized_plane(seed: u64) -> PayloadPlane {
    let mut rng = Rng::seed_from(seed);
    let precisions = mixed_precisions();
    let rows: Vec<Vec<f32>> = (0..K)
        .map(|k| {
            let mut v = vec![0.0f32; N];
            rng.fill_normal(&mut v, 0.0, 1.0);
            fake_quant(&v, precisions[k])
        })
        .collect();
    PayloadPlane::from_rows(&rows)
}

fn default_session(aggregator: Box<dyn Aggregator>, seed: u64, threads: usize) -> Session {
    let root = Rng::seed_from(seed);
    Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        aggregator,
        root.stream("channel"),
        root.stream("noise"),
        threads,
    )
}

// ---------------------------------------------------------------- goldens

#[test]
fn analog_session_bit_identical_to_enum_path() {
    let plane = quantized_plane(11);
    let precisions = mixed_precisions();
    let cfg = ChannelConfig::default();
    for threads in [1usize, 4] {
        // pre-redesign path: explicit draw + direct kernel call
        let root = Rng::seed_from(77);
        let mut channel_rng = root.stream("channel");
        let mut noise_rng = root.stream("noise");
        let pilot_seq = pilot::pilot_sequence(cfg.pilot_len);
        let mut rc = RoundChannel::empty();
        rc.draw_into(&cfg, K, &mut channel_rng, &pilot_seq);
        let mut ota_scratch = ota::analog::OtaScratch::new();
        let want_stats = ota::analog::aggregate_plane_into(
            &plane,
            &rc,
            &mut noise_rng,
            &mut ota_scratch,
            threads,
        );

        // redesigned path: the same seed through the trait seams
        let mut session = default_session(Box::new(AnalogOta), 77, threads);
        let stats = session.aggregate(1, &plane, &precisions);

        assert_eq!(session.result(), &ota_scratch.y_re[..], "threads={threads}");
        assert_eq!(stats.participants, want_stats.participants);
        assert_eq!(
            stats.mse_vs_ideal.to_bits(),
            want_stats.mse_vs_ideal.to_bits(),
            "threads={threads}"
        );
        assert_eq!(stats.noise_var.to_bits(), want_stats.noise_var.to_bits());
    }
}

#[test]
fn digital_session_bit_identical_to_enum_path() {
    let plane = quantized_plane(12);
    let precisions = mixed_precisions();
    for threads in [1usize, 4] {
        let mut agg = Vec::new();
        let want_stats =
            ota::digital::aggregate_plane_into(&plane, &precisions, &mut agg, threads);

        let mut session = default_session(Box::new(DigitalOrthogonal), 78, threads);
        let stats = session.aggregate(1, &plane, &precisions);

        assert_eq!(session.result(), &agg[..], "threads={threads}");
        assert_eq!(stats.participants, want_stats.participants);
        assert_eq!(stats.channel_uses, want_stats.channel_uses);
        assert_eq!(stats.bits_transmitted, want_stats.bits_transmitted);
    }
}

#[test]
fn ideal_session_bit_identical_to_enum_path() {
    let plane = quantized_plane(13);
    let precisions = mixed_precisions();
    for threads in [1usize, 4] {
        let mut want = Vec::new();
        fl::mean_plane_into(&plane, &mut want, threads);

        let mut session = default_session(Box::new(IdealFedAvg), 79, threads);
        let stats = session.aggregate(1, &plane, &precisions);

        assert_eq!(session.result(), &want[..], "threads={threads}");
        assert_eq!(stats.participants, K);
        assert_eq!(stats.mse_vs_ideal, 0.0);
    }
}

#[test]
fn channelless_aggregators_consume_no_randomness() {
    // the pre-redesign loop drew a channel ONLY for the analog arm; the
    // session preserves that draw-for-draw — so the digital/ideal paths
    // are seed-independent while analog is not
    let plane = quantized_plane(14);
    let precisions = mixed_precisions();
    let run = |agg: Box<dyn Aggregator>, seed: u64| -> Vec<f32> {
        let mut s = default_session(agg, seed, 1);
        s.aggregate(1, &plane, &precisions);
        s.result().to_vec()
    };
    assert_eq!(
        run(Box::new(DigitalOrthogonal), 1),
        run(Box::new(DigitalOrthogonal), 2)
    );
    assert_eq!(run(Box::new(IdealFedAvg), 1), run(Box::new(IdealFedAvg), 2));
    assert_ne!(run(Box::new(AnalogOta), 1), run(Box::new(AnalogOta), 2));
}

#[test]
fn static_policy_bit_identical_to_scheme_expansion() {
    let scheme = Scheme::parse("24,12,6").unwrap();
    let want = scheme.client_precisions(15).unwrap();
    let mut policy: Box<dyn PrecisionPolicy> = Box::new(StaticScheme::new(scheme));
    let mut out = Vec::new();
    for t in 1..=5 {
        policy
            .assign_into(
                &PolicyCtx { round: t, clients: 15, snr_db: 20.0, prev: None },
                &mut out,
            )
            .unwrap();
        assert_eq!(out, want, "round {t}");
    }
}

// ----------------------------------------------------------------- seams

/// Mock channel: fixed unit gains, silencing a chosen client — a
/// stand-in for any alternate fading/CSI model.
struct MockChannel {
    silence: usize,
    snr_db: f32,
}

impl ChannelModel for MockChannel {
    fn draw_into(&mut self, num_clients: usize, _rng: &mut Rng, out: &mut RoundChannel) {
        out.snr_db = self.snr_db;
        out.clients.clear();
        for k in 0..num_clients {
            if k == self.silence {
                out.clients.push(ClientChannel {
                    h: C32::ZERO,
                    h_est: C32::ZERO,
                    precode: Precode::Silenced,
                    effective_gain: None,
                });
            } else {
                out.clients.push(ClientChannel {
                    h: C32::ONE,
                    h_est: C32::ONE,
                    precode: Precode::Transmit(C32::ONE),
                    effective_gain: Some(C32::ONE),
                });
            }
        }
    }

    fn name(&self) -> &'static str {
        "mock"
    }
}

/// Counts every observer hook invocation.
#[derive(Default)]
struct CountingObserver {
    starts: std::rc::Rc<std::cell::RefCell<Counts>>,
}

#[derive(Default, Debug)]
struct Counts {
    round_start: usize,
    channel: usize,
    aggregate: usize,
    round_end: usize,
}

impl RoundObserver for CountingObserver {
    fn on_round_start(&mut self, _round: usize) {
        self.starts.borrow_mut().round_start += 1;
    }
    fn on_channel(&mut self, _round: usize, channel: &RoundChannel) {
        assert!(!channel.clients.is_empty());
        self.starts.borrow_mut().channel += 1;
    }
    fn on_aggregate(&mut self, _round: usize, stats: &AggregateStats) {
        assert!(stats.participants > 0);
        self.starts.borrow_mut().aggregate += 1;
    }
    fn on_round_end(&mut self, _record: &mpota::metrics::RoundRecord) {
        self.starts.borrow_mut().round_end += 1;
    }
}

#[test]
fn mock_channel_and_counting_observer_are_driven() {
    let plane = quantized_plane(15);
    let precisions = mixed_precisions();
    let counts = std::rc::Rc::new(std::cell::RefCell::new(Counts::default()));
    let root = Rng::seed_from(1);
    let mock = MockChannel {
        silence: 3,
        snr_db: 300.0, // effectively noise-free
    };
    let mut session = Session::new(
        Box::new(mock),
        Box::new(AnalogOta),
        root.stream("channel"),
        root.stream("noise"),
        1,
    );
    session.add_observer(Box::new(CountingObserver { starts: counts.clone() }));

    session.begin_round(1);
    let stats = session.aggregate(1, &plane, &precisions);
    session.end_round(&mpota::metrics::RoundRecord::default());
    session.begin_round(2);
    session.aggregate(2, &plane, &precisions);
    session.end_round(&mpota::metrics::RoundRecord::default());

    // the mock silenced exactly one client
    assert_eq!(stats.participants, K - 1);
    assert_eq!(session.channel_model_name(), "mock");
    let c = counts.borrow();
    assert_eq!(c.round_start, 2);
    assert_eq!(c.channel, 2, "one channel draw per analog round");
    assert_eq!(c.aggregate, 2);
    assert_eq!(c.round_end, 2);

    // unit gains + no noise: the aggregate is the mean of the non-silenced
    // payloads to float accuracy
    let mut want = vec![0.0f32; N];
    let mut kk = 0usize;
    for (k, row) in (0..K).map(|k| (k, plane.row(k))) {
        if k == 3 {
            continue;
        }
        kk += 1;
        for (w, &x) in want.iter_mut().zip(row.iter()) {
            *w += x;
        }
    }
    for w in want.iter_mut() {
        *w /= kk as f32;
    }
    let max_diff = session
        .result()
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "max diff {max_diff}");
}

/// Custom aggregator: coordinate-wise trimmed mean (drops the single min
/// and max across clients per element) — a Byzantine-robust baseline, and
/// proof the seam supports aggregation rules the enum never knew about.
struct TrimmedMean;

impl Aggregator for TrimmedMean {
    fn aggregate_into(
        &mut self,
        plane: &PayloadPlane,
        _ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        let k = plane.k();
        let n = plane.n();
        let out = scratch.agg_mut();
        out.resize(n, 0.0);
        out.fill(0.0);
        assert!(k > 2, "trimmed mean needs at least 3 clients");
        for i in 0..n {
            let mut sum = 0.0f32;
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for kk in 0..k {
                let v = plane.row(kk)[i];
                sum += v;
                lo = lo.min(v);
                hi = hi.max(v);
            }
            out[i] = (sum - lo - hi) / (k - 2) as f32;
        }
        AggregateStats { participants: k, ..Default::default() }
    }

    fn needs_channel(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "trimmed-mean"
    }
}

#[test]
fn custom_aggregator_runs_through_the_session() {
    let rows = vec![
        vec![0.0f32, 10.0, -5.0],
        vec![1.0f32, 20.0, 0.0],
        vec![2.0f32, 30.0, 5.0],
        vec![100.0f32, -100.0, 100.0], // outlier the trim removes
    ];
    let plane = PayloadPlane::from_rows(&rows);
    let precisions = vec![Precision::of(32); 4];
    let root = Rng::seed_from(5);
    let mut session = Session::new(
        Box::new(RayleighPilot::new(ChannelConfig::default())),
        Box::new(TrimmedMean),
        root.stream("channel"),
        root.stream("noise"),
        1,
    );
    let stats = session.aggregate(1, &plane, &precisions);
    assert_eq!(stats.participants, 4);
    assert_eq!(session.aggregator_name(), "trimmed-mean");
    // element 0: drop 0 and 100 -> (1+2)/2; element 1: drop -100 and 30
    // -> (10+20)/2; element 2: drop -5 and 100 -> (0+5)/2
    assert_eq!(session.result(), &[1.5, 15.0, 2.5]);
}

#[test]
fn session_rounds_reuse_buffers_and_stay_deterministic() {
    // two identically-seeded sessions stay in lockstep over many rounds
    let plane = quantized_plane(16);
    let precisions = mixed_precisions();
    let mut s1 = default_session(Box::new(AnalogOta), 2024, 1);
    let mut s2 = default_session(Box::new(AnalogOta), 2024, 4);
    for t in 1..=4 {
        let a = s1.aggregate(t, &plane, &precisions);
        let b = s2.aggregate(t, &plane, &precisions);
        assert_eq!(s1.result(), s2.result(), "round {t}");
        assert_eq!(a.participants, b.participants);
        assert_eq!(a.mse_vs_ideal.to_bits(), b.mse_vs_ideal.to_bits());
    }
}

// ------------------------------------------------- channel-realism pins

fn seeded_session(
    model: Box<dyn ChannelModel>,
    seed: u64,
    threads: usize,
) -> Session {
    let root = Rng::seed_from(seed);
    Session::new(
        model,
        Box::new(AnalogOta),
        root.stream("channel"),
        root.stream("noise"),
        threads,
    )
}

#[test]
fn gauss_markov_rho_zero_bit_identical_to_rayleigh_pilot() {
    // the acceptance pin: GaussMarkov with rho=0 IS the i.i.d. paper
    // pipeline — same channels, same aggregates, same RNG consumption,
    // at every thread count
    let plane = quantized_plane(21);
    let precisions = mixed_precisions();
    let cfg = ChannelConfig::default();
    assert_eq!(cfg.rho, 0.0, "default config must be the i.i.d. channel");
    for threads in [1usize, 4] {
        let mut gm = seeded_session(Box::new(GaussMarkov::new(cfg.clone())), 555, threads);
        let mut rp =
            seeded_session(Box::new(RayleighPilot::new(cfg.clone())), 555, threads);
        for t in 1..=4 {
            let a = gm.aggregate(t, &plane, &precisions);
            let b = rp.aggregate(t, &plane, &precisions);
            assert_eq!(gm.result(), rp.result(), "t={t} threads={threads}");
            assert_eq!(a.participants, b.participants);
            assert_eq!(
                a.mse_vs_ideal.to_bits(),
                b.mse_vs_ideal.to_bits(),
                "t={t} threads={threads}"
            );
            for (x, y) in gm.channel().clients.iter().zip(rp.channel().clients.iter())
            {
                assert_eq!(x.h, y.h);
                assert_eq!(x.h_est, y.h_est);
                assert_eq!(x.effective_gain, y.effective_gain);
            }
        }
    }
}

#[test]
fn stateful_channel_models_are_thread_count_invariant() {
    // bit-identical multi-round trajectories at threads=1 vs threads=4
    // for the stateful models (the channel draw itself is sequential; the
    // aggregation kernels must not perturb it or the results)
    let plane = quantized_plane(22);
    let precisions = mixed_precisions();
    let mut gm_cfg = ChannelConfig::default();
    gm_cfg.rho = 0.85;
    let builders: Vec<Box<dyn Fn() -> Box<dyn ChannelModel>>> = vec![
        Box::new({
            let c = gm_cfg.clone();
            move || -> Box<dyn ChannelModel> { Box::new(GaussMarkov::new(c.clone())) }
        }),
        Box::new(|| -> Box<dyn ChannelModel> {
            Box::new(PathLossGeometry::new(ChannelConfig::default()))
        }),
    ];
    for mk in &builders {
        let mut s1 = seeded_session(mk(), 777, 1);
        let mut s4 = seeded_session(mk(), 777, 4);
        for t in 1..=4 {
            let a = s1.aggregate(t, &plane, &precisions);
            let b = s4.aggregate(t, &plane, &precisions);
            assert_eq!(s1.result(), s4.result(), "round {t}");
            assert_eq!(a.participants, b.participants);
            assert_eq!(a.mse_vs_ideal.to_bits(), b.mse_vs_ideal.to_bits());
        }
    }
}

#[test]
fn gauss_markov_nonzero_rho_diverges_from_iid_after_round_one() {
    // sanity inverse of the rho=0 pin: with memory the trajectories must
    // actually differ from round 2 on (round 1 is the stationary init)
    let plane = quantized_plane(23);
    let precisions = mixed_precisions();
    let mut cfg = ChannelConfig::default();
    cfg.rho = 0.9;
    let mut gm = seeded_session(Box::new(GaussMarkov::new(cfg.clone())), 888, 1);
    cfg.rho = 0.0;
    let mut id = seeded_session(Box::new(GaussMarkov::new(cfg)), 888, 1);
    gm.aggregate(1, &plane, &precisions);
    id.aggregate(1, &plane, &precisions);
    assert_eq!(gm.result(), id.result(), "round 1 is the stationary draw");
    gm.aggregate(2, &plane, &precisions);
    id.aggregate(2, &plane, &precisions);
    assert_ne!(gm.result(), id.result(), "rho=0.9 must correlate round 2");
}

#[test]
fn feedback_policies_work_through_trait_objects() {
    // Box<dyn PrecisionPolicy> end to end, driven by a synthetic record
    // stream: plateau promotes on stalled loss, budget demotes on spend
    let mut plateau: Box<dyn PrecisionPolicy> =
        Box::new(LossPlateau::new().with_patience(2));
    let mut budget: Box<dyn PrecisionPolicy> = Box::new(EnergyBudget::new(1.0));
    let mut out = Vec::new();
    let clients = 6usize;
    let mut plateau_bits = Vec::new();
    let mut budget_bits = Vec::new();
    let mut rec = RoundRecord::default();
    for t in 1..=9 {
        let prev = if t == 1 { None } else { Some(&rec) };
        let ctx = PolicyCtx { round: t, clients, snr_db: 20.0, prev };
        plateau.assign_into(&ctx, &mut out).unwrap();
        plateau_bits.push(out[0].bits());
        budget.assign_into(&ctx, &mut out).unwrap();
        budget_bits.push(out[0].bits());
        // synthesize the round's record: loss stalls at 1.0, energy
        // accrues 1 J per round against a 6 J fleet budget
        rec = RoundRecord {
            round: t,
            server_loss: 1.0,
            energy_joules: t as f64,
            evaluated: true,
            ..Default::default()
        };
    }
    // loss stalls from the second observation on; patience 2
    assert_eq!(plateau_bits, vec![4, 4, 4, 6, 6, 8, 8, 12, 12]);
    // energy: spent = (t-1) J of the 6 J fleet budget; with a 7-level
    // ladder the index is floor(7·(t-1)/6), capped at the cheapest level
    assert_eq!(budget_bits, vec![32, 24, 16, 12, 8, 6, 4, 4, 4]);
}

// --------------------------------------- execution-runtime full-run pins

// The mock fixture (artifacts dir + deterministic `Sync` trainer) lives
// in `mpota::testing` so `tests/shard_invariance.rs` pins the sharded
// round pipeline against the SAME backend.
use mpota::testing::{mock_artifacts_dir as fixture_artifacts, MockTrainer};

fn full_run_cfg(
    model: FadingKind,
    workers: usize,
    threads: usize,
    dir: &std::path::Path,
) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = dir.to_path_buf();
    cfg.variant = "mock".into();
    cfg.clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = 3;
    cfg.train_samples = 96;
    cfg.test_samples = 32;
    cfg.scheme = Scheme::parse("16,8,4").unwrap();
    cfg.channel.model = model;
    if model == FadingKind::GaussMarkov {
        cfg.channel.rho = 0.85;
    }
    cfg.workers = workers;
    cfg.threads = threads;
    cfg
}

fn run_full(cfg: RunConfig, rt: Rc<Runtime>) -> (Vec<u32>, mpota::coordinator::RunReport) {
    let mut exp = Experiment::builder(cfg)
        .runtime(rt)
        .backend(MockTrainer)
        .build()
        .unwrap();
    let report = exp.run().unwrap();
    let bits: Vec<u32> = exp.global_model().iter().map(|v| v.to_bits()).collect();
    (bits, report)
}

#[test]
fn full_runs_bit_identical_across_workers_and_threads() {
    // the acceptance pin: for each channel model, the full-run trajectory
    // (global model, per-round records, final report) is bit-identical
    // per seed across every {threads, workers} ∈ {1, 4} combination —
    // client partitioning and pooled kernels change scheduling only
    let dir = fixture_artifacts("wt");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    for model in
        [FadingKind::Rayleigh, FadingKind::GaussMarkov, FadingKind::PathLoss]
    {
        let (theta_ref, rep_ref) = run_full(full_run_cfg(model, 1, 1, &dir), rt.clone());
        assert_eq!(rep_ref.log.rounds.len(), 3);
        for (w, t) in [(4usize, 1usize), (1, 4), (4, 4)] {
            let (theta, rep) = run_full(full_run_cfg(model, w, t, &dir), rt.clone());
            assert_eq!(
                theta_ref, theta,
                "{model:?}: global model diverged at workers={w} threads={t}"
            );
            for (a, b) in rep_ref.log.rounds.iter().zip(rep.log.rounds.iter()) {
                assert_eq!(a.participants, b.participants, "{model:?} w={w} t={t}");
                assert_eq!(
                    a.train_loss.to_bits(),
                    b.train_loss.to_bits(),
                    "{model:?} round {} w={w} t={t}",
                    a.round
                );
                assert_eq!(
                    a.ota_mse.to_bits(),
                    b.ota_mse.to_bits(),
                    "{model:?} round {} w={w} t={t}",
                    a.round
                );
                assert_eq!(
                    a.server_loss.to_bits(),
                    b.server_loss.to_bits(),
                    "{model:?} round {} w={w} t={t}",
                    a.round
                );
            }
            assert_eq!(
                rep_ref.final_accuracy.to_bits(),
                rep.final_accuracy.to_bits(),
                "{model:?} w={w} t={t}"
            );
        }
    }
}

#[test]
fn client_parallel_runs_actually_train_every_client() {
    // sanity on the partitioned phase itself: every selected client
    // contributed (non-default stats ⇒ train_loss finite and the model
    // moved), and manual stepping works with workers > 1
    let dir = fixture_artifacts("phase");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let cfg = full_run_cfg(FadingKind::Rayleigh, 4, 1, &dir);
    let mut exp = Experiment::builder(cfg)
        .runtime(rt)
        .backend(MockTrainer)
        .build()
        .unwrap();
    let before: Vec<f32> = exp.global_model().to_vec();
    let rec = exp.round(1).unwrap();
    // truncated inversion may silence deep-faded clients, but the round
    // must deliver at a default-SNR Rayleigh draw
    assert!(rec.participants > 0, "round lost at 20 dB");
    assert!(rec.train_loss.is_finite());
    assert_ne!(before, exp.global_model(), "aggregate must move the model");
}

#[test]
fn pjrt_gateway_fails_cleanly_without_a_runtime() {
    // workers > 1 with the default (PJRT) backend routes train steps
    // through the TrainService funnel; with the stub runtime (or missing
    // artifacts) the first step errors — the phase must propagate that
    // error and terminate, never hang a worker or the serve loop
    let dir = fixture_artifacts("gw");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mut cfg = full_run_cfg(FadingKind::Rayleigh, 4, 1, &dir);
    cfg.rounds = 1;
    let mut exp = Experiment::builder(cfg).runtime(rt).build().unwrap();
    let err = exp.round(1);
    assert!(err.is_err(), "stubbed PJRT must surface an error");
}
