//! Robustness pins for PR 6: crash-safe JSONL streaming, build-time
//! rejection of incompatible aggregator/straggler combinations (with
//! stable error text), and exec-pool panic hygiene mid-pipelined-round.

use std::rc::Rc;

use mpota::config::RunConfig;
use mpota::fl::Scheme;
use mpota::kernels::PayloadPlane;
use mpota::metrics::RoundRecord;
use mpota::ota::AggregateStats;
use mpota::runtime::{EvalResult, Runtime, TrainOutput};
use mpota::sim::{AggCtx, AggScratch, Aggregator, Experiment, JsonlStreamer};
use mpota::testing::{mock_artifacts_dir, MockTrainer};

fn base_cfg(dir: &std::path::Path) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.artifacts_dir = dir.to_path_buf();
    cfg.variant = "mock".into();
    cfg.clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = 3;
    cfg.train_samples = 96;
    cfg.test_samples = 32;
    cfg.scheme = Scheme::parse("16,8,4").unwrap();
    cfg
}

// ---------------------------------------------------------------------
// Satellite 1: JsonlStreamer crash safety.
// ---------------------------------------------------------------------

#[test]
fn aborted_stream_leaves_only_whole_jsonl_lines() {
    // every push flushes one complete line to the OS, so a process abort
    // (simulated here by mem::forget: Drop — and the BufWriter's final
    // flush — never runs) can tear or lose NOTHING already pushed
    let path = std::env::temp_dir().join("mpota_robustness_abort.jsonl");
    let _ = std::fs::remove_file(&path);
    let mut stream =
        JsonlStreamer::create(&path).unwrap().with_label("abort-test");
    for t in 0..17usize {
        let mut r = RoundRecord::default();
        r.round = t;
        r.server_accuracy = 0.01 * t as f64;
        r.participants = 6;
        r.evaluated = true;
        stream.push(&r);
        if t % 5 == 4 {
            stream.sync(); // the round-boundary fsync point
        }
    }
    std::mem::forget(stream); // abort mid-run: no Drop, no final flush

    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.ends_with('\n'), "file does not end on a line boundary");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 17, "pushed lines went missing");
    for (t, line) in lines.iter().enumerate() {
        let v = mpota::json::parse(line)
            .unwrap_or_else(|e| panic!("torn JSONL line {t}: {e}"));
        assert_eq!(v.get("round").unwrap().as_usize().unwrap(), t);
        assert_eq!(v.get("label").unwrap().as_str().unwrap(), "abort-test");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn streamer_observer_records_every_round_of_a_run() {
    // the observer wiring end-to-end: one line per round, all parseable,
    // matching the run's own log
    let dir = mock_artifacts_dir("robust_stream");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let path = std::env::temp_dir().join("mpota_robustness_observer.jsonl");
    let _ = std::fs::remove_file(&path);
    let mut exp = Experiment::builder(base_cfg(&dir))
        .runtime(rt)
        .backend(MockTrainer)
        .observe(JsonlStreamer::create(&path).unwrap().with_label("run"))
        .build()
        .unwrap();
    let report = exp.run().unwrap();
    drop(exp);
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), report.log.rounds.len());
    for (line, rec) in lines.iter().zip(report.log.rounds.iter()) {
        let v = mpota::json::parse(line).unwrap();
        assert_eq!(v.get("round").unwrap().as_usize().unwrap(), rec.round);
        assert_eq!(
            v.get("participants").unwrap().as_usize().unwrap(),
            rec.participants
        );
    }
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// Satellite 2: non-streaming aggregators are rejected at BUILD time when
// the run needs the shard protocol — with both conflicting values named.
// ---------------------------------------------------------------------

/// Minimal custom aggregator WITHOUT streaming support (the default):
/// plain mean over the materialized whole-round plane.
struct PlaneMean;

impl Aggregator for PlaneMean {
    fn aggregate_into(
        &mut self,
        plane: &PayloadPlane,
        _ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        let (k, n) = (plane.k(), plane.n());
        let out = scratch.agg_mut();
        out.clear();
        out.resize(n, 0.0);
        if k > 0 {
            let f = 1.0 / k as f32;
            for r in 0..k {
                for (o, &x) in out.iter_mut().zip(plane.row(r).iter()) {
                    *o += f * x;
                }
            }
        }
        AggregateStats { participants: k, ..Default::default() }
    }

    fn needs_channel(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "plane-mean"
    }
}

#[test]
fn non_streaming_aggregator_still_runs_whole_round_planes() {
    // control: with no shard_size and no straggler knobs the one-shot
    // protocol is used and the custom aggregator works end to end
    let dir = mock_artifacts_dir("robust_nonstream_ok");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mut exp = Experiment::builder(base_cfg(&dir))
        .runtime(rt)
        .backend(MockTrainer)
        .aggregator(PlaneMean)
        .build()
        .unwrap();
    let report = exp.run().unwrap();
    assert_eq!(report.log.rounds.len(), 3);
    assert!(report.log.rounds.iter().all(|r| r.participants == 6));
}

#[test]
fn sharded_run_with_non_streaming_aggregator_fails_at_build_time() {
    let dir = mock_artifacts_dir("robust_nonstream_shard");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mut cfg = base_cfg(&dir);
    cfg.shard_size = 2; // < clients_per_round = 6
    let err = Experiment::builder(cfg)
        .runtime(rt)
        .backend(MockTrainer)
        .aggregator(PlaneMean)
        .build()
        .err()
        .expect("shard_size < K with a non-streaming aggregator must not build");
    let msg = err.to_string();
    // the pinned shape: aggregator name + BOTH conflicting values + a fix
    assert!(
        msg.contains(
            "aggregator 'plane-mean' does not support streaming rounds: \
             shard_size 2 < clients_per_round 6"
        ),
        "unexpected error text: {msg}"
    );
    assert!(
        msg.contains("remove shard_size or use a streaming aggregator"),
        "error names no remedy: {msg}"
    );
}

#[test]
fn straggler_run_with_non_streaming_aggregator_fails_at_build_time() {
    // deadline/dropout handling is built on the masked shard protocol, so
    // it is rejected up front too — naming the policy to disable
    let dir = mock_artifacts_dir("robust_nonstream_straggler");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mut cfg = base_cfg(&dir);
    cfg.dropout_p = 0.25;
    let err = Experiment::builder(cfg)
        .runtime(rt)
        .backend(MockTrainer)
        .aggregator(PlaneMean)
        .build()
        .err()
        .expect("straggler knobs with a non-streaming aggregator must not build");
    let msg = err.to_string();
    assert!(
        msg.contains(
            "aggregator 'plane-mean' does not support streaming rounds, \
             which straggler handling requires"
        ),
        "unexpected error text: {msg}"
    );
    assert!(
        msg.contains("disable the 'virtual-clock' deadline/dropout policy"),
        "error names no remedy: {msg}"
    );
}

// ---------------------------------------------------------------------
// Satellite 3: a panic on a pool worker mid-pipelined-round propagates to
// the caller without poisoning the process-global pool or the arena.
// ---------------------------------------------------------------------

/// MockTrainer that panics for every 4-bit client — under the "16,8,4"
/// scheme that detonates mid-round, while other clients of the same
/// dispatch are still training and the previous super-shard is being
/// superposed.
struct PanicAt4Bits;

impl mpota::exec::TrainBackend for PanicAt4Bits {
    fn train_step(
        &self,
        p: mpota::quant::Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> anyhow::Result<TrainOutput> {
        if p.bits() == 4 {
            panic!("injected trainer panic");
        }
        MockTrainer.train_step(p, theta, images, labels, lr)
    }

    fn evaluate(
        &self,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> anyhow::Result<EvalResult> {
        MockTrainer.evaluate(theta, images, labels)
    }
}

#[test]
fn worker_panic_mid_pipelined_round_propagates_and_pool_survives() {
    let dir = mock_artifacts_dir("robust_panic");
    let rt = Rc::new(Runtime::load(&dir).unwrap());
    let mk = |depth: usize| {
        let mut cfg = base_cfg(&dir);
        cfg.shard_size = 1;
        cfg.pipeline_depth = depth;
        cfg.workers = 4;
        cfg
    };

    // the panic travels off the worker and out of run() with its payload
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut exp = Experiment::builder(mk(2))
            .runtime(rt.clone())
            .backend(PanicAt4Bits)
            .build()
            .unwrap();
        exp.run().map(|r| r.log.rounds.len())
    }));
    let payload = result.expect_err("injected panic was swallowed");
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_default();
    assert!(
        msg.contains("injected trainer panic"),
        "panic payload was replaced: {msg:?}"
    );

    // the process-global pool is unpoisoned: a fresh pipelined experiment
    // on the SAME pool still reproduces the serial trajectory bit for bit
    let run = |cfg: RunConfig| {
        let mut exp = Experiment::builder(cfg)
            .runtime(rt.clone())
            .backend(MockTrainer)
            .build()
            .unwrap();
        let report = exp.run().unwrap();
        let bits: Vec<u32> =
            exp.global_model().iter().map(|v| v.to_bits()).collect();
        (bits, report.final_loss.to_bits())
    };
    let serial = run(base_cfg(&dir));
    let pipelined = run(mk(2));
    assert_eq!(serial, pipelined, "pool state leaked across the panic");
}
