//! Hot-path microbenchmarks (criterion is not in the vendored set; this is
//! a small warmup+trimmed-mean harness with ns/op and throughput output).
//!
//! Covers the L3 request-path kernels the §Perf pass optimizes:
//!   * OTA analog superposition (K=15 complex-gain accumulate + noise)
//!   * Algorithm-2 quantization (fixed-point and float-trunc)
//!   * digital-baseline encode/decode
//!   * Rayleigh channel round draw (pilot estimation included)
//!   * fedavg / vector kernels
//!   * PJRT train-step + eval dispatch (if artifacts are present)
//!
//! Run: `cargo bench --bench hotpaths`

use std::time::Instant;

use mpota::channel::{ChannelConfig, RoundChannel};
use mpota::ota;
use mpota::quant::{self, Precision};
use mpota::rng::Rng;

/// warmup + measure: returns (secs_per_iter, iters)
fn bench<F: FnMut()>(label: &str, bytes_per_iter: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut samples = Vec::new();
    let target = std::time::Duration::from_millis(600);
    let t_all = Instant::now();
    let mut iters = 0u64;
    while t_all.elapsed() < target || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        iters += 1;
        if iters > 10_000 {
            break;
        }
    }
    samples.sort_by(f64::total_cmp);
    // trimmed mean of the middle 60%
    let lo = samples.len() / 5;
    let hi = samples.len() - lo;
    let mean: f64 = samples[lo..hi].iter().sum::<f64>() / (hi - lo) as f64;
    let gbps = bytes_per_iter as f64 / mean / 1e9;
    if bytes_per_iter > 0 {
        println!("{label:<44} {:>12.3} ms/iter {:>9.2} GB/s", mean * 1e3, gbps);
    } else {
        println!("{label:<44} {:>12.3} ms/iter", mean * 1e3);
    }
    mean
}

fn main() {
    println!("=== hotpaths: L3 request-path microbenchmarks ===\n");
    let k = 15usize;
    let n = 142_720usize; // flagship param count: the real payload size
    let root = Rng::seed_from(1);

    // payloads
    let mut rng = root.stream("bench");
    let payloads: Vec<Vec<f32>> = (0..k)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect();
    let cfg = ChannelConfig::default();
    let round = RoundChannel::draw(&cfg, k, &mut rng);

    // --- OTA analog aggregation (the paper's aggregation hot path) ------
    let payload_bytes = k * n * 4;
    bench("ota::analog::aggregate (15 x 142720 f32)", payload_bytes, || {
        let mut noise_rng = Rng::seed_from(7);
        let (agg, _) = ota::analog::aggregate(&payloads, &round, &mut noise_rng);
        std::hint::black_box(agg);
    });

    // --- digital baseline ------------------------------------------------
    let precisions: Vec<Precision> =
        (0..k).map(|i| Precision::of([32u8, 8, 4][i % 3])).collect();
    bench("ota::digital::aggregate (encode+decode+avg)", payload_bytes, || {
        let (agg, _) = ota::digital::aggregate(&payloads, &precisions);
        std::hint::black_box(agg);
    });

    // --- quantization -----------------------------------------------------
    let src = payloads[0].clone();
    let mut buf = src.clone();
    bench("quant fixed-point 4-bit (142720 f32)", n * 4, || {
        buf.copy_from_slice(&src);
        quant::fake_quant_inplace(&mut buf, Precision::of(4));
        std::hint::black_box(&buf);
    });
    bench("quant float-trunc 16-bit (142720 f32)", n * 4, || {
        buf.copy_from_slice(&src);
        quant::fake_quant_inplace(&mut buf, Precision::of(16));
        std::hint::black_box(&buf);
    });

    // --- channel simulation ----------------------------------------------
    bench("RoundChannel::draw (15 clients, 16-pilot LS)", 0, || {
        let mut ch_rng = Rng::seed_from(3);
        let rc = RoundChannel::draw(&cfg, k, &mut ch_rng);
        std::hint::black_box(rc);
    });

    // --- fedavg oracle ----------------------------------------------------
    bench("fl::mean (15 x 142720 f32)", payload_bytes, || {
        let m = mpota::fl::mean(&payloads);
        std::hint::black_box(m);
    });

    // --- data generation ---------------------------------------------------
    bench("signs::render 32x32 sample", 0, || {
        let mut r = Rng::seed_from(11);
        let img = mpota::data::signs::render(7, &mut r);
        std::hint::black_box(img);
    });

    // --- PJRT dispatch (needs artifacts) -----------------------------------
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = mpota::runtime::Runtime::load(&dir).unwrap();
        let theta = rt.init_params("base").unwrap();
        let mut drng = Rng::seed_from(5);
        let data = mpota::data::Dataset::generate(64, &mut drng);
        let (images, labels) = (
            data.images[..32 * mpota::data::SAMPLE_LEN].to_vec(),
            data.labels[..32].to_vec(),
        );
        for bits in [32u8, 8, 4] {
            // compile outside the timed region
            rt.train_step("base", Precision::of(bits), &theta, &images, &labels, 0.01)
                .unwrap();
            bench(&format!("PJRT train_step base q{bits} (batch 32)"), 0, || {
                let out = rt
                    .train_step(
                        "base",
                        Precision::of(bits),
                        &theta,
                        &images,
                        &labels,
                        0.01,
                    )
                    .unwrap();
                std::hint::black_box(out);
            });
        }
        bench("PJRT evaluate base (64 samples)", 0, || {
            let r = rt
                .evaluate("base", &theta, &data.images, &data.labels)
                .unwrap();
            std::hint::black_box(r);
        });
    } else {
        println!("(PJRT benches skipped: run `make artifacts` first)");
    }
}
