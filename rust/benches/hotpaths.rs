//! Hot-path microbenchmarks (criterion is not in the vendored set; this is
//! a small warmup+trimmed-mean harness with ns/op and throughput output).
//!
//! Covers the L3 request-path kernels the §Perf pass optimizes:
//!   * OTA analog superposition (K=15 complex-gain accumulate + noise):
//!     pre-PR scalar reference vs the fused payload-plane kernel at
//!     threads=1 and threads=num_cpus
//!   * Algorithm-2 quantization (fixed-point and float-trunc):
//!     copy-then-inplace scalar reference vs fused quantize-into
//!   * receiver-noise fill (sequential vs skip-ahead parallel Box-Muller)
//!   * digital-baseline aggregation (frame encode/decode vs fused plane)
//!   * fedavg (vec-of-vecs vs plane), channel round draw, data generation
//!   * exec-pool dispatch latency (persistent parked pool vs per-call
//!     scoped spawning) and `workers`-scaling of the client
//!     quantize/modulate phase (row-partitioned plane writes)
//!   * pipelined vs serial streaming round (PR-6: payload generation of
//!     super-shard t+1 overlapping superposition of super-shard t)
//!   * id-keyed stateful channel draws (all-resident slot==id hits vs a
//!     constantly-evicting Floyd-sampled 64-of-1M `draw_for`)
//!   * bit-packed payload transport (PR-9: f32-staged fused superpose vs
//!     the unpack-fuse-superpose packed kernel at 4/8/16-bit widths)
//!   * PJRT train-step + eval dispatch (artifacts + `pjrt` feature only)
//!
//! Run: `cargo bench --bench hotpaths`
//! Budget: `MPOTA_BENCH_MS` (per-label wall budget, default 600 ms — set a
//! small value for CI smoke runs).
//! Output: human table on stdout plus machine-readable
//! `BENCH_hotpaths.json` at the repo root (override: `MPOTA_BENCH_JSON`).

use std::time::Instant;

use mpota::channel::{ChannelConfig, RoundChannel, C32};
use mpota::fl::Selection;
use mpota::json::Value;
use mpota::kernels::{fused, par, PackedPlane, PayloadPlane};
use mpota::ota::{self, analog::OtaScratch};
use mpota::quant::{self, Precision, Rounding};
use mpota::rng::Rng;
use mpota::sim::{ChannelModel, GaussMarkov};

/// Per-label wall-clock budget (ms), overridable for CI smoke runs.
fn bench_budget_ms() -> u64 {
    std::env::var("MPOTA_BENCH_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(600)
}

/// (label, secs_per_iter, GB/s) rows collected for the JSON emit.
struct Results {
    budget: std::time::Duration,
    rows: Vec<(String, f64, f64)>,
}

impl Results {
    fn new() -> Self {
        Results {
            budget: std::time::Duration::from_millis(bench_budget_ms()),
            rows: Vec::new(),
        }
    }

    /// warmup + measure; records and returns secs_per_iter.
    fn bench<F: FnMut()>(&mut self, label: &str, bytes_per_iter: usize, mut f: F) -> f64 {
        // warmup
        for _ in 0..3 {
            f();
        }
        let mut samples = Vec::new();
        let t_all = Instant::now();
        let mut iters = 0u64;
        // keep collecting until the budget elapses AND we have at least 5
        // samples (so the trim below has a middle to keep); the iteration
        // cap bounds pathological cases
        while t_all.elapsed() < self.budget || samples.len() < 5 {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
            if iters > 10_000 {
                break;
            }
        }
        samples.sort_by(f64::total_cmp);
        // trimmed mean of the middle 60% — but only when there are enough
        // samples for the trim to leave a non-empty middle (tiny budgets /
        // early breaks previously made lo == hi and panicked on the empty
        // slice mean)
        let len = samples.len();
        let (lo, hi) = if len >= 5 { (len / 5, len - len / 5) } else { (0, len) };
        let mid = &samples[lo..hi];
        let mean: f64 = mid.iter().sum::<f64>() / mid.len() as f64;
        let gbps = if bytes_per_iter > 0 {
            bytes_per_iter as f64 / mean / 1e9
        } else {
            0.0
        };
        if bytes_per_iter > 0 {
            println!("{label:<52} {:>12.3} ms/iter {:>9.2} GB/s", mean * 1e3, gbps);
        } else {
            println!("{label:<52} {:>12.3} ms/iter", mean * 1e3);
        }
        self.rows.push((label.to_string(), mean, gbps));
        mean
    }

    fn to_json(&self, k: usize, n: usize, threads_max: usize) -> Value {
        let mut labels = Value::object();
        for (label, secs, gbps) in &self.rows {
            let mut row = Value::object();
            row.set("ns_per_op", Value::Num(secs * 1e9));
            row.set("ms_per_iter", Value::Num(secs * 1e3));
            row.set("gbps", Value::Num(*gbps));
            labels.set(label, row);
        }
        let mut o = Value::object();
        o.set("bench", Value::Str("hotpaths".into()));
        o.set("k", Value::Num(k as f64));
        o.set("n", Value::Num(n as f64));
        o.set("threads_max", Value::Num(threads_max as f64));
        o.set("budget_ms", Value::Num(bench_budget_ms() as f64));
        o.set("labels", labels);
        o
    }
}

// The pre-PR scalar aggregation baseline lives in `mpota::testing`
// (`reference_ota_aggregate`) — the SAME function the golden tests pin the
// fused kernels against, so the published speedups and the bit-exactness
// contract always reference one baseline.

fn speedup(labels: &mut Value, name: &str, base: f64, new: f64) {
    let s = base / new;
    println!("  speedup {name:<44} {s:>6.2}x");
    labels.set(name, Value::Num(s));
}

fn main() {
    println!("=== hotpaths: L3 request-path microbenchmarks ===\n");
    let k = 15usize;
    let n = 142_720usize; // flagship param count: the real payload size
    let ncpu = par::auto_threads();
    let root = Rng::seed_from(1);
    let mut res = Results::new();
    println!("(budget {} ms/label, {} hardware threads)\n", bench_budget_ms(), ncpu);

    // payloads
    let mut rng = root.stream("bench");
    let payloads: Vec<Vec<f32>> = (0..k)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect();
    let cfg = ChannelConfig::default();
    let round = RoundChannel::draw(&cfg, k, &mut rng);
    let plane = PayloadPlane::from_rows(&payloads);

    // --- OTA analog aggregation (the paper's aggregation hot path) ------
    let payload_bytes = k * n * 4;
    let scalar_agg =
        res.bench("ota::analog aggregate scalar-reference", payload_bytes, || {
            let mut noise_rng = Rng::seed_from(7);
            let agg = mpota::testing::reference_ota_aggregate(&payloads, &round, &mut noise_rng);
            std::hint::black_box(agg);
        });
    let mut scratch = OtaScratch::new();
    let fused_t1 = res.bench("ota::analog aggregate fused threads=1", payload_bytes, || {
        let mut noise_rng = Rng::seed_from(7);
        let stats =
            ota::analog::aggregate_plane_into(&plane, &round, &mut noise_rng, &mut scratch, 1);
        std::hint::black_box((&scratch.y_re, stats.participants));
    });
    // threads=ncpu rows only exist on multi-core machines: at ncpu == 1
    // they would duplicate (and silently overwrite) the threads=1 labels
    let fused_tn = (ncpu > 1).then(|| {
        let label_tn = format!("ota::analog aggregate fused threads={ncpu}");
        res.bench(&label_tn, payload_bytes, || {
            let mut noise_rng = Rng::seed_from(7);
            let stats = ota::analog::aggregate_plane_into(
                &plane,
                &round,
                &mut noise_rng,
                &mut scratch,
                ncpu,
            );
            std::hint::black_box((&scratch.y_re, stats.participants));
        })
    });

    // --- receiver-noise fill --------------------------------------------
    let noise_bytes = 2 * n * 4;
    let mut nre = vec![0.0f32; n];
    let mut nim = vec![0.0f32; n];
    let noise_seq = res.bench("noise add_normal re+im sequential", noise_bytes, || {
        let mut r = Rng::seed_from(11);
        r.add_normal(&mut nre, 0.3);
        r.add_normal(&mut nim, 0.3);
        std::hint::black_box((&nre, &nim));
    });
    let label_noise = format!("noise add_normal2 skip-ahead threads={ncpu}");
    let noise_par = res.bench(&label_noise, noise_bytes, || {
        let mut r = Rng::seed_from(11);
        r.add_normal2(&mut nre, &mut nim, 0.3, ncpu);
        std::hint::black_box((&nre, &nim));
    });

    // --- digital baseline ------------------------------------------------
    let precisions: Vec<Precision> =
        (0..k).map(|i| Precision::of([32u8, 8, 4][i % 3])).collect();
    let dig_scalar = res.bench("ota::digital aggregate frame-reference", payload_bytes, || {
        let (agg, _) = ota::digital::aggregate(&payloads, &precisions);
        std::hint::black_box(agg);
    });
    let mut dig_out = Vec::new();
    let label_dig = format!("ota::digital aggregate fused plane threads={ncpu}");
    let dig_fused = res.bench(&label_dig, payload_bytes, || {
        let stats =
            ota::digital::aggregate_plane_into(&plane, &precisions, &mut dig_out, ncpu);
        std::hint::black_box((&dig_out, stats.participants));
    });

    // --- quantization -----------------------------------------------------
    let src = payloads[0].clone();
    let mut buf = src.clone();
    let q4_scalar = res.bench("quant fixed 4-bit copy+inplace reference", n * 4, || {
        buf.copy_from_slice(&src);
        quant::fake_quant_inplace(&mut buf, Precision::of(4));
        std::hint::black_box(&buf);
    });
    let q4_t1 = res.bench("quant fixed 4-bit fused-into threads=1", n * 4, || {
        quant::fake_quant_into(&mut buf, &src, Precision::of(4), Rounding::Floor, 1);
        std::hint::black_box(&buf);
    });
    let q4_tn = (ncpu > 1).then(|| {
        let label_q4 = format!("quant fixed 4-bit fused-into threads={ncpu}");
        res.bench(&label_q4, n * 4, || {
            quant::fake_quant_into(&mut buf, &src, Precision::of(4), Rounding::Floor, ncpu);
            std::hint::black_box(&buf);
        })
    });
    let q16_scalar = res.bench("quant float 16-bit copy+inplace reference", n * 4, || {
        buf.copy_from_slice(&src);
        quant::fake_quant_inplace(&mut buf, Precision::of(16));
        std::hint::black_box(&buf);
    });
    let q16_t1 = res.bench("quant float 16-bit fused-into threads=1", n * 4, || {
        quant::fake_quant_into(&mut buf, &src, Precision::of(16), Rounding::Floor, 1);
        std::hint::black_box(&buf);
    });
    let q16_tn = (ncpu > 1).then(|| {
        let label_q16 = format!("quant float 16-bit fused-into threads={ncpu}");
        res.bench(&label_q16, n * 4, || {
            quant::fake_quant_into(&mut buf, &src, Precision::of(16), Rounding::Floor, ncpu);
            std::hint::black_box(&buf);
        })
    });

    // --- channel simulation ----------------------------------------------
    res.bench("RoundChannel::draw (15 clients, 16-pilot LS)", 0, || {
        let mut ch_rng = Rng::seed_from(3);
        let rc = RoundChannel::draw(&cfg, k, &mut ch_rng);
        std::hint::black_box(rc);
    });

    // --- fedavg oracle ----------------------------------------------------
    let mean_scalar = res.bench("fl::mean vec-of-vecs reference", payload_bytes, || {
        let m = mpota::fl::mean(&payloads);
        std::hint::black_box(m);
    });
    let mut mean_out = Vec::new();
    let label_mean = format!("fl::mean_plane_into threads={ncpu}");
    let mean_fused = res.bench(&label_mean, payload_bytes, || {
        mpota::fl::fedavg::mean_plane_into(&plane, &mut mean_out, ncpu);
        std::hint::black_box(&mean_out);
    });

    // --- data generation ---------------------------------------------------
    res.bench("signs::render 32x32 sample", 0, || {
        let mut r = Rng::seed_from(11);
        let img = mpota::data::signs::render(7, &mut r);
        std::hint::black_box(img);
    });

    // --- exec-pool dispatch latency ----------------------------------------
    // tiny per-task work (1k-element sum): what remains is the cost of
    // getting 4 tasks onto threads and back — per-call scoped spawning
    // pays thread creation + stack allocation; the parked pool only pays
    // a wake + join handshake
    let tiny: Vec<f32> = (0..1024).map(|i| (i % 97) as f32).collect();
    let spawn_lat = res.bench("dispatch scoped-spawn 4 threads (1k sum)", 0, || {
        let mut acc = [0.0f32; 4];
        // mpota-lint: allow(R2): the scoped-spawn baseline this bench compares the pool against
        std::thread::scope(|s| {
            for (i, slot) in acc.iter_mut().enumerate() {
                let tiny = &tiny;
                s.spawn(move || {
                    *slot = tiny.iter().sum::<f32>() + i as f32;
                });
            }
        });
        std::hint::black_box(acc);
    });
    let pool_lat = res.bench("dispatch pool broadcast 4 tasks (1k sum)", 0, || {
        let acc: [std::sync::atomic::AtomicU32; 4] =
            std::array::from_fn(|_| std::sync::atomic::AtomicU32::new(0));
        let tiny_ref = &tiny;
        let acc_ref = &acc;
        let task = |i: usize| {
            let v = tiny_ref.iter().sum::<f32>() + i as f32;
            acc_ref[i].store(v.to_bits(), std::sync::atomic::Ordering::Relaxed);
        };
        mpota::exec::pool().broadcast(4, &task);
        std::hint::black_box(&acc);
    });

    // --- client-phase workers scaling --------------------------------------
    // the quantize/modulate half of local_round_into, row-partitioned
    // across pool workers exactly like the coordinator's client phase
    // (K clients' payload rows, mixed 16/8/4-bit fused quantize-into)
    let mut cplane = vec![0.0f32; k * n];
    let levels = [Precision::of(16), Precision::of(8), Precision::of(4)];
    let theta_src = &payloads[0];
    let client_phase = |workers: usize, buf: &mut [f32]| {
        par::par_row_partition_mut(workers, k, buf, |r0, chunk| {
            for (i, row) in chunk.chunks_mut(n).enumerate() {
                quant::fake_quant_into(
                    row,
                    theta_src,
                    levels[(r0 + i) % 3],
                    Rounding::Nearest,
                    1,
                );
            }
        });
    };
    let cp_w1 = res.bench("client phase quantize/modulate workers=1", k * n * 4, || {
        client_phase(1, &mut cplane);
        std::hint::black_box(&cplane);
    });
    // label with the EFFECTIVE worker count (bounded by the K rows), so
    // the recorded key never overstates the measured parallelism
    let cp_workers = ncpu.min(k);
    let cp_wn = (cp_workers > 1).then(|| {
        let label = format!("client phase quantize/modulate workers={cp_workers}");
        res.bench(&label, k * n * 4, || {
            client_phase(cp_workers, &mut cplane);
            std::hint::black_box(&cplane);
        })
    });

    // --- massive-fleet sharded round ---------------------------------------
    // N = 1M clients, K = 64 selected, 4096-element payloads.  The seed
    // path materialized a 0..N permutation buffer per round (dense
    // partial Fisher-Yates) and the full K×n plane, aggregated one-shot;
    // the fleet path samples K with Floyd's algorithm (O(K) state) and
    // streams 16-row shards through the persistent air accumulator.
    // Results are bit-identical by the shard-invariance contract; the
    // speedup is the removed O(N) per-round selection work (and the K×n
    // plane shrinking to shard×n is the memory win).
    let (fleet_dense, fleet_sharded) = {
        let fleet = 1_000_000usize;
        let ksel = 64usize;
        let nn = 4096usize;
        let shard = 16usize;
        let fcfg = ChannelConfig::default();
        let mut fch_rng = Rng::seed_from(21);
        let fround = RoundChannel::draw(&fcfg, ksel, &mut fch_rng);
        let fbytes = ksel * nn * 4;
        let mut dense_sel: Vec<usize> = Vec::new();
        let mut fplane = PayloadPlane::zeros(ksel, nn);
        let mut fscratch = OtaScratch::new();
        let dense = res.bench(
            "fleet round dense-select unsharded (N=1M K=64)",
            fbytes,
            || {
                // seed-era UniformK: full 0..N permutation scratch
                let mut srng = Rng::seed_from(55);
                dense_sel.clear();
                dense_sel.extend(0..fleet);
                for i in 0..ksel {
                    let j = i + srng.below(fleet - i);
                    dense_sel.swap(i, j);
                }
                dense_sel.truncate(ksel);
                dense_sel.sort_unstable();
                // whole-round K×n plane, aggregated one-shot
                let mut prng = Rng::seed_from(13);
                for r in 0..ksel {
                    prng.fill_normal(fplane.row_mut(r), 0.0, 1.0);
                }
                let mut noise_rng = Rng::seed_from(7);
                let stats = ota::analog::aggregate_plane_into(
                    &fplane,
                    &fround,
                    &mut noise_rng,
                    &mut fscratch,
                    1,
                );
                std::hint::black_box((&dense_sel, stats.participants));
            },
        );
        let mut sel: Vec<usize> = Vec::new();
        let mut splane = PayloadPlane::zeros(shard, nn);
        let sharded = res.bench(
            "fleet round sampled sharded s=16 (N=1M K=64)",
            fbytes,
            || {
                let mut srng = Rng::seed_from(55);
                Selection::SampledK(ksel).select_into(fleet, 1, &mut srng, &mut sel);
                let mut prng = Rng::seed_from(13);
                let mut noise_rng = Rng::seed_from(7);
                ota::analog::begin_plane_into(nn, &mut fscratch);
                let mut lo = 0usize;
                while lo < ksel {
                    let hi = (lo + shard).min(ksel);
                    splane.reset(hi - lo, nn);
                    for r in 0..(hi - lo) {
                        prng.fill_normal(splane.row_mut(r), 0.0, 1.0);
                    }
                    ota::analog::accumulate_plane_into(
                        &splane,
                        lo,
                        &fround,
                        &mut fscratch,
                        1,
                    );
                    lo = hi;
                }
                let stats = ota::analog::finalize_plane_into(
                    &fround,
                    &mut noise_rng,
                    &mut fscratch,
                    1,
                );
                std::hint::black_box((&sel, stats.participants));
            },
        );
        (dense, sharded)
    };

    // --- id-keyed channel state: the LRU path's round overhead -------------
    // the identity-keying fix routes every stateful channel draw through a
    // bounded id-keyed LRU (capacity 2·K).  Baseline: the slot==id compat
    // path with a fully resident window (full participation — every lookup
    // an LRU hit, the cheapest the keyed path gets).  Contender: Floyd's
    // sampling of 64 identities out of 1M, where virtually every id is a
    // fresh insert that evicts the LRU tail (the worst case the fix must
    // not slow down).  The recorded ratio ≈ 1.0 is the claim: keying
    // per-client fading state by identity costs nothing at round scale.
    let (idlru_hit, idlru_miss) = {
        let ksel = 64usize;
        let fleet = 1_000_000usize;
        let rounds_per_iter = 8usize;
        let mut gm_cfg = ChannelConfig::default();
        gm_cfg.rho = 0.9;
        let mut hit_model = GaussMarkov::new(gm_cfg.clone());
        let mut rc = RoundChannel::empty();
        let hit = res.bench("channel GaussMarkov slot==id K=64 resident-hits", 0, || {
            let mut ch_rng = Rng::seed_from(17);
            for _ in 0..rounds_per_iter {
                hit_model.draw_into(ksel, &mut ch_rng, &mut rc);
            }
            std::hint::black_box(rc.clients.len());
        });
        let mut miss_model = GaussMarkov::new(gm_cfg);
        let mut sel: Vec<usize> = Vec::new();
        let miss = res.bench("channel GaussMarkov draw_for 64-of-1M evicting", 0, || {
            let mut srng = Rng::seed_from(55);
            let mut ch_rng = Rng::seed_from(17);
            for t in 1..=rounds_per_iter {
                Selection::SampledK(ksel).select_into(fleet, t, &mut srng, &mut sel);
                miss_model.draw_for(&sel, &mut ch_rng, &mut rc);
            }
            std::hint::black_box(rc.clients.len());
        });
        (hit, miss)
    };

    // --- pipelined vs serial round (PR-6 overlap engine) -------------------
    // the async round engine's wall win: client payload generation of
    // super-shard t+1 (Box-Muller fill + fused 4-bit quantize — the
    // "training" half) overlaps the superposition of super-shard t on the
    // exec pool, double-buffered exactly like Coordinator::pipeline_step.
    // Bit-identity of the two paths is pinned by
    // tests/shard_invariance.rs; this measures the overlap.
    let (round_serial, round_pipelined) = {
        struct SendMut<T>(*mut T);
        // SAFETY: each pointer is dereferenced by exactly one task of the
        // blocking dispatch below, and the pointee outlives the dispatch.
        unsafe impl<T> Send for SendMut<T> {}
        unsafe impl<T> Sync for SendMut<T> {}

        fn fill_shard(plane: &mut PayloadPlane, rng: &mut Rng) {
            for r in 0..plane.k() {
                rng.fill_normal(plane.row_mut(r), 0.0, 1.0);
                quant::fake_quant_inplace(plane.row_mut(r), Precision::of(4));
            }
        }

        let ksel = 16usize;
        let nn = n; // flagship payload size
        let step = 4usize;
        let pcfg = ChannelConfig::default();
        let mut pch_rng = Rng::seed_from(31);
        let pround = RoundChannel::draw(&pcfg, ksel, &mut pch_rng);
        let pbytes = ksel * nn * 4;
        let mut pscratch = OtaScratch::new();
        let mut plane_a = PayloadPlane::zeros(step, nn);
        let mut plane_b = PayloadPlane::zeros(step, nn);

        let serial = res.bench(
            "round serial fill-then-superpose (K=16 s=4)",
            pbytes,
            || {
                let mut prng = Rng::seed_from(13);
                let mut noise_rng = Rng::seed_from(7);
                ota::analog::begin_plane_into(nn, &mut pscratch);
                let mut lo = 0usize;
                while lo < ksel {
                    let hi = (lo + step).min(ksel);
                    plane_a.reset(hi - lo, nn);
                    fill_shard(&mut plane_a, &mut prng);
                    ota::analog::accumulate_plane_into(
                        &plane_a,
                        lo,
                        &pround,
                        &mut pscratch,
                        1,
                    );
                    lo = hi;
                }
                let stats = ota::analog::finalize_plane_into(
                    &pround,
                    &mut noise_rng,
                    &mut pscratch,
                    1,
                );
                std::hint::black_box(stats.participants);
            },
        );
        let pool = mpota::exec::pool();
        let pipelined = res.bench(
            "round pipelined overlap depth=1 (K=16 s=4)",
            pbytes,
            || {
                let mut prng = Rng::seed_from(13);
                let mut noise_rng = Rng::seed_from(7);
                ota::analog::begin_plane_into(nn, &mut pscratch);
                plane_a.reset(step, nn);
                fill_shard(&mut plane_a, &mut prng);
                let mut lo = 0usize; // start of the filled super-shard
                let mut cur_in_b = true;
                while lo + step < ksel {
                    let cur_lo = lo + step;
                    let cur_hi = (cur_lo + step).min(ksel);
                    let (prev_plane, cur_plane) = if cur_in_b {
                        (&plane_a, &mut plane_b)
                    } else {
                        (&plane_b, &mut plane_a)
                    };
                    {
                        let scratch_ptr =
                            SendMut(&mut pscratch as *mut OtaScratch);
                        let prng_ptr = SendMut(&mut prng as *mut Rng);
                        let cur_ptr = SendMut(cur_plane as *mut PayloadPlane);
                        let prev_ref: &PayloadPlane = prev_plane;
                        let pround_ref = &pround;
                        let task = move |i: usize| {
                            if i == 0 {
                                // SAFETY: sole scratch toucher this dispatch
                                let s = unsafe { &mut *scratch_ptr.0 };
                                ota::analog::accumulate_plane_into(
                                    prev_ref, lo, pround_ref, s, 1,
                                );
                            } else {
                                // SAFETY: sole toucher of the idle plane+rng
                                let p = unsafe { &mut *cur_ptr.0 };
                                let r = unsafe { &mut *prng_ptr.0 };
                                p.reset(cur_hi - cur_lo, nn);
                                fill_shard(p, r);
                            }
                        };
                        pool.broadcast(2, &task);
                    }
                    lo = cur_lo;
                    cur_in_b = !cur_in_b;
                }
                let last = if cur_in_b { &plane_a } else { &plane_b };
                ota::analog::accumulate_plane_into(
                    last,
                    lo,
                    &pround,
                    &mut pscratch,
                    1,
                );
                let stats = ota::analog::finalize_plane_into(
                    &pround,
                    &mut noise_rng,
                    &mut pscratch,
                    1,
                );
                std::hint::black_box(stats.participants);
            },
        );
        (serial, pipelined)
    };

    // --- packed planes: bit-packed transport vs f32 staging (PR-9) ---------
    // K = 64 uniform-width rows at the flagship payload size.  Baseline:
    // fake-quantize every row into an f32 plane and run the fused f32
    // superpose — what the packed-off transport streams.  Contender: pack
    // the SAME raw rows and run the unpack-fuse-superpose kernel over the
    // packed words.  The two paths are bit-identical by construction
    // (pinned in tests/packed_plane.rs), so the speedup is pure memory
    // traffic: a 4-bit row moves 1/8th of the bytes of its f32 form.
    let packed_pairs = {
        let pk = 64usize;
        let mut prng = root.stream("packed-bench");
        let mut raw = PayloadPlane::zeros(pk, n);
        for r in 0..pk {
            prng.fill_normal(raw.row_mut(r), 0.0, 1.0);
        }
        // all rows active, unit-magnitude rotating gains
        let active: Vec<(usize, C32)> =
            (0..pk).map(|r| (r, C32::from_polar(1.0, 0.37 * r as f32))).collect();
        let mut y_re = vec![0.0f32; n];
        let mut y_im = vec![0.0f32; n];
        let mut ideal = vec![0.0f32; n];
        let mut fq_plane = PayloadPlane::zeros(pk, n);
        let mut packed = PackedPlane::new();
        let mut pairs: Vec<(u8, f64, f64, usize)> = Vec::new();
        for bits in [4u8, 8, 16] {
            let p = Precision::of(bits);
            let precisions = vec![p; pk];
            // stage both transports from the same raw rows
            packed.reset(&precisions, n);
            for r in 0..pk {
                let row = fq_plane.row_mut(r);
                row.copy_from_slice(raw.row(r));
                quant::fake_quant_inplace(row, p);
                packed.pack_row(r, raw.row(r));
            }
            let base = res.bench(
                &format!("superpose f32-staged {bits}-bit rows (K=64)"),
                pk * n * 4,
                || {
                    y_re.fill(0.0);
                    y_im.fill(0.0);
                    ideal.fill(0.0);
                    fused::superpose(&fq_plane, &active, &mut y_re, &mut y_im, &mut ideal, 1);
                    std::hint::black_box((&y_re, &y_im, &ideal));
                },
            );
            let pk_bytes: usize = (0..pk).map(|r| packed.row_bytes(r)).sum();
            let pkd = res.bench(
                &format!("superpose bit-packed {bits}-bit rows (K=64)"),
                pk_bytes,
                || {
                    y_re.fill(0.0);
                    y_im.fill(0.0);
                    ideal.fill(0.0);
                    fused::superpose_packed(
                        &packed, &active, &mut y_re, &mut y_im, &mut ideal, 1,
                    );
                    std::hint::black_box((&y_re, &y_im, &ideal));
                },
            );
            pairs.push((bits, base, pkd, packed.row_bytes(0)));
        }
        pairs
    };

    // --- PJRT dispatch (needs artifacts + the pjrt feature) ----------------
    let dir = std::path::PathBuf::from("artifacts");
    if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
        let rt = mpota::runtime::Runtime::load(&dir).unwrap();
        let theta = rt.init_params("base").unwrap();
        let mut drng = Rng::seed_from(5);
        let data = mpota::data::Dataset::generate(64, &mut drng);
        let (images, labels) = (
            data.images[..32 * mpota::data::SAMPLE_LEN].to_vec(),
            data.labels[..32].to_vec(),
        );
        for bits in [32u8, 8, 4] {
            // compile outside the timed region
            rt.train_step("base", Precision::of(bits), &theta, &images, &labels, 0.01)
                .unwrap();
            res.bench(&format!("PJRT train_step base q{bits} (batch 32)"), 0, || {
                let out = rt
                    .train_step(
                        "base",
                        Precision::of(bits),
                        &theta,
                        &images,
                        &labels,
                        0.01,
                    )
                    .unwrap();
                std::hint::black_box(out);
            });
        }
        res.bench("PJRT evaluate base (64 samples)", 0, || {
            let r = rt
                .evaluate("base", &theta, &data.images, &data.labels)
                .unwrap();
            std::hint::black_box(r);
        });
    } else {
        println!("(PJRT benches skipped: need artifacts + --features pjrt)");
    }

    // --- summary + machine-readable emit -----------------------------------
    println!("\n—— speedups vs pre-PR scalar references ——");
    let mut speedups = Value::object();
    speedup(&mut speedups, "analog_fused_t1", scalar_agg, fused_t1);
    if let Some(t) = fused_tn {
        speedup(&mut speedups, &format!("analog_fused_t{ncpu}"), scalar_agg, t);
    }
    speedup(&mut speedups, "noise_skip_ahead", noise_seq, noise_par);
    speedup(&mut speedups, "digital_fused_plane", dig_scalar, dig_fused);
    speedup(&mut speedups, "quant_fixed4_fused_t1", q4_scalar, q4_t1);
    if let Some(t) = q4_tn {
        speedup(&mut speedups, &format!("quant_fixed4_fused_t{ncpu}"), q4_scalar, t);
    }
    speedup(&mut speedups, "quant_float16_fused_t1", q16_scalar, q16_t1);
    if let Some(t) = q16_tn {
        speedup(&mut speedups, &format!("quant_float16_fused_t{ncpu}"), q16_scalar, t);
    }
    speedup(&mut speedups, "fedavg_mean_plane", mean_scalar, mean_fused);
    speedup(&mut speedups, "pool_dispatch_vs_spawn", spawn_lat, pool_lat);
    speedup(&mut speedups, "fleet_scaling_k1000000", fleet_dense, fleet_sharded);
    speedup(&mut speedups, "fleet_round_id_lru", idlru_hit, idlru_miss);
    speedup(&mut speedups, "pipelined_vs_serial_round", round_serial, round_pipelined);
    for &(bits, base, pkd, _) in &packed_pairs {
        speedup(&mut speedups, &format!("packed_superpose_{bits}bit_vs_f32"), base, pkd);
    }
    if let Some(t) = cp_wn {
        let cp_workers = ncpu.min(k);
        speedup(
            &mut speedups,
            &format!("client_phase_workers_{cp_workers}"),
            cp_w1,
            t,
        );
    }

    let mut doc = res.to_json(k, n, ncpu);
    doc.set("speedups", speedups);
    // packed storage footprint at the flagship payload size (bytes/row)
    let mut bytes_row = Value::object();
    bytes_row.set("f32", Value::Num((n * 4) as f64));
    for &(bits, _, _, b) in &packed_pairs {
        bytes_row.set(&format!("{bits}bit"), Value::Num(b as f64));
    }
    doc.set("packed_plane_bytes_per_row", bytes_row);
    let path = std::env::var("MPOTA_BENCH_JSON").unwrap_or_else(|_| {
        // cargo runs benches with CWD = package root (rust/); the perf
        // trajectory file lives at the repo root next to ROADMAP.md
        if std::path::Path::new("../ROADMAP.md").exists() {
            "../BENCH_hotpaths.json".to_string()
        } else {
            "BENCH_hotpaths.json".to_string()
        }
    });
    std::fs::write(&path, doc.to_string_pretty()).expect("writing bench json");
    println!("\nwrote {path}");
}
