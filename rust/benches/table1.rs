//! Regenerates **Table I**: classification accuracy of f32-trained CNN
//! models post-training-quantized to 8/6/4/3/2 bits (paper §II-C).
//!
//! The paper's zoo (densenet-161 … squeezenet on GTSRB) maps to the
//! SignNet variant family on synthetic signs (DESIGN.md §2): each variant
//! is trained centrally at f32, then Algorithm-2-quantized per level and
//! evaluated.  The expected *shape* (what the paper's colour coding says):
//! 8/6-bit ≈ f32, 4-bit noticeably damaged but usable, 3/2-bit collapse.
//!
//! Run: `cargo bench --bench table1` (optionally MPOTA_T1_EPOCHS=n)

use mpota::coordinator::pretrain::{pretrain, PretrainConfig};
use mpota::data::Dataset;
use mpota::quant::{Precision, Rounding};
use mpota::rng::Rng;
use mpota::runtime::Runtime;

const PTQ_LEVELS: [u8; 5] = [8, 6, 4, 3, 2];

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = Runtime::load(&dir)?;
    let epochs: usize = std::env::var("MPOTA_T1_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);

    // held-out evaluation corpus (same generator family, fresh stream)
    let mut eval_rng = Rng::seed_from(99).stream("table1-eval");
    let test = Dataset::generate(860, &mut eval_rng);

    println!("=== Table I reproduction: PTQ accuracy across quantization levels ===");
    println!(
        "(SignNet variants stand in for the paper's ImageNet-pretrained zoo; \
         f32 central training, {epochs} epochs, then Algorithm-2 PTQ)\n"
    );
    print!("{:<8} {:>8}", "model", "f32");
    for b in PTQ_LEVELS {
        print!("{:>8}", format!("{b}-bit"));
    }
    println!();

    let variants: Vec<String> = rt.manifest.variants.keys().cloned().collect();
    let mut rows: Vec<(String, f64, Vec<f64>)> = Vec::new();
    for name in &variants {
        let cfg = PretrainConfig {
            variant: name.clone(),
            samples: 3072,
            epochs,
            lr: 0.1,
            seed: 17,
        };
        let (theta, _) = pretrain(&rt, &cfg)?;
        let base = rt.evaluate(name, &theta, &test.images, &test.labels)?;
        let mut accs = Vec::new();
        print!("{:<8} {:>7.2}%", name, 100.0 * base.accuracy);
        // fused chunk-parallel PTQ kernel: bit-identical per seed at any
        // MPOTA_THREADS value
        let threads = mpota::kernels::par::env_threads();
        for b in PTQ_LEVELS {
            // per-layer Algorithm-2 PTQ (floor), paper §III-B semantics
            let q = rt.quantize_model_par(
                name,
                &theta,
                Precision::of(b),
                Rounding::Floor,
                threads,
            )?;
            let r = rt.evaluate(name, &q, &test.images, &test.labels)?;
            accs.push(r.accuracy);
            print!("{:>7.2}%", 100.0 * r.accuracy);
        }
        println!();
        rows.push((name.clone(), base.accuracy, accs));
    }

    // ---- shape checks vs the paper's colour bands -----------------------
    println!("\nshape checks (paper Table I):");
    let mut ok = true;
    for (name, f32_acc, accs) in &rows {
        // 8-bit and 6-bit stay close to f32 (paper: degradation only
        // "noticeable" at 8-bit)
        let near = accs[0] > f32_acc - 0.10 && accs[1] > f32_acc - 0.12;
        // 2-bit collapses far below 8-bit
        let collapse = accs[4] < accs[0] - 0.20 || accs[4] < 0.20;
        // monotone-ish: lower bits never much better
        let mono = accs[0] + 0.05 >= accs[2] && accs[2] + 0.05 >= accs[4];
        let pass = near && collapse && mono;
        ok &= pass;
        println!(
            "  {name:<8} 8/6-bit≈f32: {near}, 2-bit collapse: {collapse}, \
             monotone: {mono} -> {}",
            if pass { "PASS" } else { "FAIL" }
        );
    }
    if !ok {
        println!("WARNING: some shape checks failed (undertrained models?)");
    }
    Ok(())
}
