//! Regenerates **Fig. 3**: server training accuracy over communication
//! rounds for the paper's precision schemes (paper §IV-B2).
//!
//! Scaled for one CPU core: default 12 rounds / reduced corpus (override
//! with MPOTA_F3_ROUNDS / MPOTA_F3_SAMPLES).  Expected shape: schemes
//! containing >=16-bit clients converge fast and smoothly; [4,4,4] and
//! [12,4,4] converge slower and erratically; 32-bit adds little over
//! 16-bit.
//!
//! Run: `cargo bench --bench fig3`

use std::rc::Rc;

use mpota::config::RunConfig;
use mpota::coordinator::pretrain;
use mpota::fl::Scheme;
use mpota::metrics::RunLog;
use mpota::runtime::Runtime;
use mpota::sim::{Arena, Experiment};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }
    let rounds = env_usize("MPOTA_F3_ROUNDS", 6);
    let samples = env_usize("MPOTA_F3_SAMPLES", 1920);

    // one runtime for all eight runs: artifacts compile once, and the
    // recycled arena keeps the server buffers allocated once
    let runtime = Rc::new(Runtime::load(&dir)?);
    // pretrained init = the paper's "ImageNet pre-trained initialization"
    let pretrained =
        pretrain::ensure_pretrained(&runtime, &pretrain::PretrainConfig::default())?;

    let schemes = Scheme::paper_schemes();
    println!(
        "=== Fig. 3 reproduction: server accuracy vs round ({rounds} rounds, \
         15 clients, pretrained init, 20 dB SNR) ==="
    );

    let mut arena = Arena::default();
    let mut curves: Vec<(String, RunLog)> = Vec::new();
    for scheme in &schemes {
        let mut cfg = RunConfig::default();
        cfg.rounds = rounds;
        cfg.scheme = scheme.clone();
        cfg.train_samples = samples;
        cfg.test_samples = 384;
        cfg.local_steps = 2;
        cfg.lr = 0.02;
        cfg.init_params = Some(pretrained.clone());
        cfg.threads = mpota::kernels::par::env_threads();
        let mut exp = Experiment::builder(cfg)
            .runtime(runtime.clone())
            .arena(arena)
            .build()?;
        let report = exp.run()?;
        arena = exp.into_arena();
        eprintln!(
            "[{}] final {:.3} best {:.3} instab {:.4}",
            scheme,
            report.final_accuracy,
            report.log.best_accuracy(),
            report.log.early_instability(rounds)
        );
        curves.push((scheme.to_string(), report.log));
    }

    // ---- the figure, as a text series table ------------------------------
    print!("\n{:<10}", "round");
    for (label, _) in &curves {
        print!("{:>10}", label);
    }
    println!();
    for r in 0..rounds {
        print!("{:<10}", r + 1);
        for (_, log) in &curves {
            print!("{:>10.4}", log.rounds[r].server_accuracy);
        }
        println!();
    }

    // ---- shape checks ----------------------------------------------------
    let acc_of = |label: &str| {
        curves
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, log)| log.final_accuracy())
            .unwrap()
    };
    let instab_of = |label: &str| {
        curves
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, log)| log.early_instability(rounds))
            .unwrap()
    };
    println!("\nshape checks (paper Fig. 3):");
    let slow_low = acc_of("4,4,4") <= acc_of("16,16,16") + 0.02;
    println!("  [4,4,4] converges no faster than [16,16,16]: {slow_low}");
    let marginal_32 = (acc_of("32,32,32") - acc_of("16,16,16")).abs() < 0.10;
    println!("  32-bit only marginal gain over 16-bit: {marginal_32}");
    let erratic = instab_of("4,4,4") + instab_of("12,4,4")
        >= instab_of("32,16,8") + instab_of("16,16,16") - 1e-6;
    println!("  low-precision schemes more erratic: {erratic}");

    // persist curves for fig4 / plotting
    let out = std::path::PathBuf::from("runs/fig3");
    for (label, log) in &curves {
        log.write_files(&out, &label.replace(',', "_"))?;
    }
    println!("\ncurves written to runs/fig3/*.csv");
    Ok(())
}
