//! Regenerates **Fig. 4**: trade-off between the accuracy of the final
//! global model quantized to 4-bit (the ultra-low-precision clients' view)
//! and energy savings vs homogeneous 32-bit / 16-bit fleets (paper §IV-B3).
//!
//! Each scheme is run to completion, the final model is re-quantized to
//! 4-bit and evaluated, and the fleet energy is compared against the
//! homogeneous counterfactuals on identical MAC workloads.
//!
//! Expected shape: mixed schemes save 65%+ vs 32-bit while the 4-bit view
//! of schemes containing >=16-bit clients gains ~5-10 points over the
//! homogeneous [4,4,4] fleet; vs 8-bit fleets, mixing trades ~10% energy
//! for ~5% accuracy.
//!
//! Run: `cargo bench --bench fig4`

use mpota::config::RunConfig;
use mpota::coordinator::{pretrain, Coordinator};
use mpota::fl::Scheme;
use mpota::quant::Precision;
use mpota::runtime::Runtime;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Row {
    scheme: String,
    acc4: f64,
    server_acc: f64,
    joules: f64,
    save32: f64,
    save16: f64,
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }
    let rounds = env_usize("MPOTA_F4_ROUNDS", 6);
    let samples = env_usize("MPOTA_F4_SAMPLES", 1920);
    let pretrained = {
        let rt = Runtime::load(&dir)?;
        pretrain::ensure_pretrained(&rt, &pretrain::PretrainConfig::default())?
    };

    // Fig. 4's population: schemes with 4-bit clients + the homogeneous
    // reference fleets.
    let schemes = [
        "4,4,4", "8,8,8", "16,16,16", "32,32,32", // homogeneous references
        "12,4,4", "16,8,4", "24,8,4", "32,16,4", "16,4,4", "24,12,6",
    ];

    println!(
        "=== Fig. 4 reproduction: 4-bit accuracy vs energy savings \
         ({rounds} rounds, pretrained init) ==="
    );
    let mut rows = Vec::new();
    for s in schemes {
        let mut cfg = RunConfig::default();
        cfg.rounds = rounds;
        cfg.scheme = Scheme::parse(s)?;
        cfg.train_samples = samples;
        cfg.test_samples = 384;
        cfg.local_steps = 2;
        cfg.lr = 0.02;
        cfg.init_params = Some(pretrained.clone());
        // bit-identical per seed at any thread count; opt-in wall-clock win
        cfg.threads = mpota::kernels::par::env_threads();
        let mut coord = Coordinator::new(cfg)?;
        let report = coord.run()?;
        let acc4 = match report.requant.iter().find(|r| r.precision.bits() == 4) {
            Some(r) => r.accuracy,
            None => {
                let q = coord.requantize_global(Precision::of(4));
                coord.evaluate_model(&q)?.accuracy
            }
        };
        rows.push(Row {
            scheme: s.to_string(),
            acc4,
            server_acc: report.final_accuracy,
            joules: report.energy.actual_joules,
            save32: report.energy.saving_vs_32(),
            save16: report.energy.saving_vs_16(),
        });
        eprintln!("[{s}] done: acc4 {acc4:.3}");
    }

    println!(
        "\n{:<10} {:>10} {:>11} {:>11} {:>11} {:>11}",
        "scheme", "acc@4bit", "server-acc", "energy (J)", "save vs32", "save vs16"
    );
    for r in &rows {
        println!(
            "{:<10} {:>9.2}% {:>10.2}% {:>11.2} {:>10.1}% {:>10.1}%",
            r.scheme,
            100.0 * r.acc4,
            100.0 * r.server_acc,
            r.joules,
            r.save32,
            r.save16
        );
    }

    // ---- shape checks ----------------------------------------------------
    let get = |s: &str| rows.iter().find(|r| r.scheme == s).unwrap();
    println!("\nshape checks (paper Fig. 4):");
    let mixed_save = get("16,8,4").save32 > 65.0;
    println!("  mixed scheme saves >65% vs homogeneous 32-bit: {mixed_save}");
    let mixed_save16 = get("16,8,4").save16 > 13.0;
    println!("  mixed scheme saves >13% vs homogeneous 16-bit: {mixed_save16}");
    let best_mixed_acc4 = ["16,8,4", "32,16,4", "24,8,4", "16,4,4"]
        .iter()
        .map(|s| get(s).acc4)
        .fold(0.0f64, f64::max);
    let boost = best_mixed_acc4 - get("4,4,4").acc4;
    println!(
        "  best mixed 4-bit view vs homogeneous [4,4,4]: {:+.1} points \
         (paper: >10)",
        100.0 * boost
    );
    let diminishing = get("32,16,4").acc4 - get("16,8,4").acc4 < 0.08;
    println!("  boost from >16-bit partners shows diminishing returns: {diminishing}");
    Ok(())
}
