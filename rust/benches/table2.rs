//! Regenerates **Table II**: estimated energy consumption per sample for a
//! ResNet-50 forward pass, and relative savings vs 32-bit, averaged over
//! the nine FPGA platforms (paper §IV-B1).
//!
//! Also prints the same table for the SignNet flagship (the model the FL
//! experiments actually train) and the per-platform breakdown, since the
//! paper notes the variance across hardware.
//!
//! Run: `cargo bench --bench table2`

use mpota::energy::{
    energy_joules, mean_energy_joules, saving_vs_f32, Platform, PLATFORMS,
    RESNET50_MACS_PER_SAMPLE,
};
use mpota::quant::Precision;

const LEVELS: [u8; 6] = [32, 16, 12, 8, 6, 4];

fn row(levels: &[u8], macs: f64) -> (Vec<f64>, Vec<f64>) {
    let energies: Vec<f64> = levels
        .iter()
        .map(|&b| mean_energy_joules(Precision::of(b), macs))
        .collect();
    let savings: Vec<f64> = levels
        .iter()
        .map(|&b| saving_vs_f32(Precision::of(b), macs))
        .collect();
    (energies, savings)
}

fn print_table(title: &str, macs: f64) {
    println!("\n{title}  (D_ML = {macs:.3e} MACs)");
    print!("{:<18}", "");
    for b in LEVELS {
        print!("{:>10}", format!("{b}-bit"));
    }
    println!();
    let (energies, savings) = row(&LEVELS, macs);
    print!("{:<18}", "Energy Cost (J)");
    for e in &energies {
        print!("{:>10}", format_sig(*e));
    }
    println!();
    print!("{:<18}", "Saving (%)");
    for s in &savings {
        print!("{:>10.2}", s);
    }
    println!();
}

fn format_sig(v: f64) -> String {
    if v >= 0.1 {
        format!("{v:.2}")
    } else if v >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.4}")
    }
}

fn main() {
    println!("=== Table II reproduction: energy per sample & savings vs 32-bit ===");
    println!("paper reference (avg of 9 platforms, ResNet-50 fwd):");
    println!("  32-bit 0.36 J | 16-bit 0.17 J (52.6%) | 12-bit 0.16 J (56.2%)");
    println!("  8-bit 0.022 J (93.9%) | 6-bit 0.021 J (94.2%) | 4-bit 0.0056 J (98.5%)");

    print_table(
        "ResNet-50 forward pass (the paper's workload)",
        RESNET50_MACS_PER_SAMPLE,
    );

    // the model this repo actually trains (manifest MACs if available;
    // MPOTA_T2_MACS overrides for what-if sweeps without artifacts)
    let signnet_macs = std::env::var("MPOTA_T2_MACS")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or_else(|| {
            match mpota::runtime::Manifest::load(std::path::Path::new("artifacts")) {
                Ok(m) => {
                    m.variant("base").map(|v| v.macs_per_sample as f64).unwrap_or(1.0e7)
                }
                Err(_) => 1.0e7,
            }
        });
    print_table("SignNet-base forward pass (this repo's workload)", signnet_macs);

    println!("\nper-platform energy at ResNet-50 fwd (J/sample):");
    print!("{:<10}", "platform");
    for b in LEVELS {
        print!("{:>10}", format!("{b}-bit"));
    }
    println!();
    for plat in &PLATFORMS {
        print_platform_row(plat);
    }

    // shape assertions (who-wins / plateau structure, DESIGN.md §4)
    let (e, s) = row(&LEVELS, RESNET50_MACS_PER_SAMPLE);
    assert!(e.windows(2).all(|w| w[1] <= w[0] * 1.0001), "energy must fall with bits");
    assert!((e[1] - e[2]).abs() / e[1] < 0.10, "16≈12-bit plateau");
    assert!((e[3] - e[4]).abs() / e[3] < 0.10, "8≈6-bit plateau");
    assert!(s[5] - s[3] < s[3] - s[1], "diminishing returns 8→4 vs 16→8");
    println!("\nshape checks vs paper Table II: PASS (plateaus + diminishing returns)");
}

fn print_platform_row(plat: &Platform) {
    print!("{:<10}", plat.name);
    for b in LEVELS {
        let e = energy_joules(plat, Precision::of(b), RESNET50_MACS_PER_SAMPLE);
        print!("{:>10}", format_sig(e));
    }
    println!();
}
