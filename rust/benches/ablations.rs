//! Ablations over the paper's experimental dimensions that Figs. 3/4
//! aggregate away:
//!
//!   * the 5-30 dB SNR band (§IV-A: "5-30dB of emulated Gaussian noise")
//!   * pilot-based vs perfect CSI (Eq. 5's estimation error)
//!   * update- vs weight-transmission (Alg. 1 step 10/14 vs step 18 —
//!     DESIGN.md §3 decision 3)
//!   * full vs partial participation (K < N client selection, §II-A)
//!   * IID vs Dirichlet non-IID sharding (extension knob)
//!
//! Run: `cargo bench --bench ablations`  (MPOTA_AB_ROUNDS to scale)

use mpota::config::{RunConfig, Transmit};
use mpota::coordinator::{pretrain, Coordinator};
use mpota::fl::Scheme;
use mpota::runtime::Runtime;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn base_cfg(rounds: usize, pretrained: &std::path::Path) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.rounds = rounds;
    cfg.scheme = Scheme::parse("16,8,4").unwrap();
    cfg.train_samples = 1920;
    cfg.test_samples = 384;
    cfg.local_steps = 2;
    cfg.lr = 0.02;
    cfg.init_params = Some(pretrained.to_path_buf());
    // server-kernel parallelism: results are bit-identical per seed at any
    // thread count, so this only changes wall-clock
    cfg.threads = mpota::kernels::par::env_threads();
    cfg
}

fn run(cfg: RunConfig) -> anyhow::Result<(f64, f64)> {
    let mut coord = Coordinator::new(cfg)?;
    let report = coord.run()?;
    let mean_mse = report.log.rounds.iter().map(|r| r.ota_mse).sum::<f64>()
        / report.log.rounds.len() as f64;
    Ok((report.final_accuracy, mean_mse))
}

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }
    let rounds = env_usize("MPOTA_AB_ROUNDS", 3);
    let pretrained = {
        let rt = Runtime::load(&dir)?;
        pretrain::ensure_pretrained(&rt, &pretrain::PretrainConfig::default())?
    };

    println!("=== ablations (scheme 16,8,4, {rounds} rounds) ===\n");

    // ---- SNR band (paper §IV-A) -----------------------------------------
    println!("{:<26} {:>10} {:>14}", "SNR", "final acc", "mean OTA MSE");
    let mut mses = Vec::new();
    for snr in [5.0f32, 10.0, 20.0, 30.0] {
        let mut cfg = base_cfg(rounds, &pretrained);
        cfg.channel.snr_db = snr;
        let (acc, mse) = run(cfg)?;
        println!("{:<26} {:>10.4} {:>14.3e}", format!("{snr} dB"), acc, mse);
        mses.push(mse);
    }
    assert!(mses[0] > mses[3], "MSE must fall across the 5→30 dB band");

    // ---- CSI quality (Eq. 5) --------------------------------------------
    println!("\n{:<26} {:>10} {:>14}", "CSI", "final acc", "mean OTA MSE");
    for (label, perfect, pilot_len) in
        [("perfect", true, 16usize), ("LS pilot x16", false, 16), ("LS pilot x4", false, 4)]
    {
        let mut cfg = base_cfg(rounds, &pretrained);
        cfg.channel.perfect_csi = perfect;
        cfg.channel.pilot_len = pilot_len;
        let (acc, mse) = run(cfg)?;
        println!("{label:<26} {acc:>10.4} {mse:>14.3e}");
    }

    // ---- transmit mode (DESIGN.md §3.3) ----------------------------------
    println!("\n{:<26} {:>10}", "payload", "final acc");
    let mut accs = Vec::new();
    for (label, mode) in
        [("updates (Alg.1 §10/14)", Transmit::Updates), ("weights (Alg.1 §18)", Transmit::Weights)]
    {
        let mut cfg = base_cfg(rounds, &pretrained);
        cfg.transmit = mode;
        let (acc, _) = run(cfg)?;
        println!("{label:<26} {acc:>10.4}");
        accs.push(acc);
    }
    println!(
        "  -> update-transmission advantage: {:+.1} accuracy points",
        100.0 * (accs[0] - accs[1])
    );

    // ---- participation (K of N, §II-A) -----------------------------------
    println!("\n{:<26} {:>10}", "participation", "final acc");
    for k in [15usize, 9, 6] {
        let mut cfg = base_cfg(rounds, &pretrained);
        cfg.clients_per_round = k;
        // scheme groups must divide the SELECTED count each round; keep all
        // 15 clients but sample k of them
        let (acc, _) = run(cfg)?;
        println!("{:<26} {acc:>10.4}", format!("K={k} of 15"));
    }

    println!("\nablations complete");
    Ok(())
}
